//! Metrics: per-round records and CSV/JSONL sinks.
//!
//! The experiment drivers log one [`RoundRecord`] per evaluation interval;
//! the figures' axes (test error vs comm rounds, vs cumulative bits) are
//! projections of these records.

use std::fmt::Write as _;
use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;

use crate::util::json::Json;

/// JSON encoding for one f64 metric: JSON has no NaN/Inf, so non-finite
/// values encode as their `Display` strings ("inf"/"-inf"/"NaN") and
/// the round-trip is lossless (a diverging run's loss = inf must not
/// come back as NaN after a sweep resume). The single source of truth
/// for this convention — series records and the sweep runner's
/// truncation metadata both use it.
pub fn float_json(x: f64) -> Json {
    if x.is_finite() {
        Json::Num(x)
    } else {
        Json::Str(format!("{x}"))
    }
}

/// Lossy inverse of [`float_json`] for optional metadata fields:
/// numbers pass through, parseable strings ("inf"/"NaN") decode, and
/// anything else (including legacy `null`) maps to NaN. Record parsing
/// proper ([`RoundRecord::from_json`]) stays strict instead.
pub fn json_f64_lossy(j: &Json) -> f64 {
    match j {
        Json::Num(x) => *x,
        Json::Str(s) => s.parse().unwrap_or(f64::NAN),
        _ => f64::NAN,
    }
}

/// One evaluated point of a training run.
#[derive(Clone, Debug, PartialEq)]
pub struct RoundRecord {
    /// Iteration t.
    pub t: u64,
    /// Global objective f(x̄).
    pub loss: f64,
    /// Test error in [0,1] (NaN if the problem has none).
    pub test_error: f64,
    /// f(x̄) − f* if the optimum is known (NaN otherwise).
    pub opt_gap: f64,
    /// Cumulative bits transmitted so far.
    pub bits: u64,
    /// Cumulative communication rounds so far.
    pub comm_rounds: u64,
    /// Σ_i ‖x_i − x̄‖² at this point.
    pub consensus: f64,
    /// Nodes that fired the trigger at the last sync round.
    pub fired: usize,
}

impl RoundRecord {
    pub fn csv_header() -> &'static str {
        "t,loss,test_error,opt_gap,bits,comm_rounds,consensus,fired"
    }

    pub fn to_csv(&self) -> String {
        format!(
            "{},{:.6e},{:.6},{:.6e},{},{},{:.6e},{}",
            self.t,
            self.loss,
            self.test_error,
            self.opt_gap,
            self.bits,
            self.comm_rounds,
            self.consensus,
            self.fired
        )
    }

    pub fn to_json(&self) -> Json {
        let float = float_json;
        Json::obj()
            .set("t", self.t)
            .set("loss", float(self.loss))
            .set("test_error", float(self.test_error))
            .set("opt_gap", float(self.opt_gap))
            .set("bits", self.bits)
            .set("comm_rounds", self.comm_rounds)
            .set("consensus", float(self.consensus))
            .set("fired", self.fired)
    }

    /// Inverse of [`to_json`](Self::to_json) — exact for every
    /// representable record: finite f64 values are printed in shortest
    /// round-trip form, non-finite values round-trip through their string
    /// encodings ("NaN"/"inf"/"-inf"; legacy `null` also maps to NaN),
    /// and the u64 counters stay below 2⁵³ in any realizable run.
    pub fn from_json(j: &Json) -> Result<RoundRecord, String> {
        let f = |k: &str| -> Result<f64, String> {
            match j.get(k) {
                None => Err(format!("record is missing key {k:?}")),
                Some(Json::Null) => Ok(f64::NAN),
                Some(Json::Str(s)) => s
                    .parse::<f64>()
                    .map_err(|_| format!("record key {k:?} has non-numeric string {s:?}")),
                Some(v) => v
                    .as_f64()
                    .ok_or_else(|| format!("record key {k:?} is not a number")),
            }
        };
        let u = |k: &str| -> Result<u64, String> {
            let x = f(k)?;
            if !x.is_finite() || x < 0.0 || x.fract() != 0.0 {
                return Err(format!("record key {k:?} is not a non-negative integer"));
            }
            Ok(x as u64)
        };
        Ok(RoundRecord {
            t: u("t")?,
            loss: f("loss")?,
            test_error: f("test_error")?,
            opt_gap: f("opt_gap")?,
            bits: u("bits")?,
            comm_rounds: u("comm_rounds")?,
            consensus: f("consensus")?,
            fired: u("fired")? as usize,
        })
    }
}

/// A labelled series of records (one algorithm's curve).
#[derive(Clone, Debug, Default)]
pub struct Series {
    pub label: String,
    pub records: Vec<RoundRecord>,
}

impl Series {
    pub fn new(label: impl Into<String>) -> Series {
        Series {
            label: label.into(),
            records: Vec::new(),
        }
    }

    pub fn push(&mut self, r: RoundRecord) {
        self.records.push(r);
    }

    /// First record reaching `test_error <= target`, if any.
    pub fn first_reaching_error(&self, target: f64) -> Option<&RoundRecord> {
        self.records.iter().find(|r| r.test_error <= target)
    }

    /// First record reaching `loss <= target`, if any.
    pub fn first_reaching_loss(&self, target: f64) -> Option<&RoundRecord> {
        self.records.iter().find(|r| r.loss <= target)
    }

    /// Render as CSV text.
    pub fn to_csv(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "# series: {}", self.label);
        let _ = writeln!(s, "{}", RoundRecord::csv_header());
        for r in &self.records {
            let _ = writeln!(s, "{}", r.to_csv());
        }
        s
    }

    pub fn write_csv(&self, path: &Path) -> std::io::Result<()> {
        let mut f = BufWriter::new(File::create(path)?);
        f.write_all(self.to_csv().as_bytes())
    }

    pub fn write_jsonl(&self, path: &Path) -> std::io::Result<()> {
        let mut f = BufWriter::new(File::create(path)?);
        for r in &self.records {
            writeln!(f, "{}", r.to_json().to_string())?;
        }
        Ok(())
    }

    /// Load a series previously written with
    /// [`write_jsonl`](Self::write_jsonl) (sweep resume reads completed
    /// runs back instead of re-running them).
    pub fn read_jsonl(path: &Path, label: impl Into<String>) -> std::io::Result<Series> {
        let text = std::fs::read_to_string(path)?;
        let mut series = Series::new(label);
        for (lineno, line) in text.lines().enumerate() {
            if line.trim().is_empty() {
                continue;
            }
            let j = Json::parse(line).map_err(|e| {
                std::io::Error::new(
                    std::io::ErrorKind::InvalidData,
                    format!("{}:{}: {e}", path.display(), lineno + 1),
                )
            })?;
            let r = RoundRecord::from_json(&j).map_err(|e| {
                std::io::Error::new(
                    std::io::ErrorKind::InvalidData,
                    format!("{}:{}: {e}", path.display(), lineno + 1),
                )
            })?;
            series.push(r);
        }
        Ok(series)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(t: u64, err: f64, bits: u64) -> RoundRecord {
        RoundRecord {
            t,
            loss: err * 2.0,
            test_error: err,
            opt_gap: f64::NAN,
            bits,
            comm_rounds: t,
            consensus: 0.0,
            fired: 1,
        }
    }

    #[test]
    fn first_reaching() {
        let mut s = Series::new("x");
        s.push(rec(0, 0.9, 10));
        s.push(rec(10, 0.5, 20));
        s.push(rec(20, 0.2, 30));
        assert_eq!(s.first_reaching_error(0.5).unwrap().t, 10);
        assert_eq!(s.first_reaching_error(0.1), None);
    }

    #[test]
    fn csv_roundtrip_fields() {
        let r = rec(5, 0.25, 100);
        let line = r.to_csv();
        let fields: Vec<&str> = line.split(',').collect();
        assert_eq!(fields.len(), 8);
        assert_eq!(fields[0], "5");
        assert_eq!(fields[4], "100");
    }

    #[test]
    fn jsonl_is_valid_json() {
        let r = rec(3, 0.4, 77);
        let j = r.to_json();
        let parsed = crate::util::json::Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.get("bits").unwrap().as_usize(), Some(77));
    }

    #[test]
    fn jsonl_roundtrip_is_exact_including_nan_and_inf() {
        let mut s = Series::new("rt");
        s.push(rec(0, 0.912345678901234, 10));
        s.push(RoundRecord {
            t: 7,
            loss: 1.0 / 3.0,
            test_error: f64::NAN, // → "NaN" → NaN
            opt_gap: f64::NAN,
            bits: 123_456_789,
            comm_rounds: 42,
            consensus: 2.5e-17,
            fired: 3,
        });
        s.push(RoundRecord {
            t: 9,
            loss: f64::INFINITY, // diverging run — must NOT load back as NaN
            test_error: f64::NAN,
            opt_gap: f64::NEG_INFINITY,
            bits: 1,
            comm_rounds: 1,
            consensus: 0.0,
            fired: 0,
        });
        let path =
            std::env::temp_dir().join(format!("sparq-series-{}.jsonl", std::process::id()));
        s.write_jsonl(&path).unwrap();
        let back = Series::read_jsonl(&path, "rt").unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(back.records.len(), 3);
        // every float is bit-equal (NaN payloads normalize to the one NaN
        // Display emits, which to_bits-compares equal to f64::NAN)
        for (a, b) in s.records.iter().zip(back.records.iter()) {
            assert_eq!(a.t, b.t);
            assert_eq!(a.loss.to_bits(), b.loss.to_bits());
            assert_eq!(a.test_error.to_bits(), b.test_error.to_bits());
            assert_eq!(a.opt_gap.to_bits(), b.opt_gap.to_bits());
            assert_eq!(a.bits, b.bits);
            assert_eq!(a.comm_rounds, b.comm_rounds);
            assert_eq!(a.consensus.to_bits(), b.consensus.to_bits());
            assert_eq!(a.fired, b.fired);
        }
        assert!(back.records[2].loss.is_infinite() && back.records[2].loss > 0.0);
        assert!(back.records[2].opt_gap.is_infinite() && back.records[2].opt_gap < 0.0);
        // legacy null still maps to NaN
        let legacy = r#"{"t":1,"loss":null,"test_error":null,"opt_gap":null,"bits":0,"comm_rounds":0,"consensus":0,"fired":0}"#;
        let j = crate::util::json::Json::parse(legacy).unwrap();
        assert!(RoundRecord::from_json(&j).unwrap().loss.is_nan());
    }

    #[test]
    fn read_jsonl_rejects_malformed_lines() {
        let path =
            std::env::temp_dir().join(format!("sparq-series-bad-{}.jsonl", std::process::id()));
        std::fs::write(&path, "{\"t\": 1}\n").unwrap();
        let err = Series::read_jsonl(&path, "x").unwrap_err();
        assert!(err.to_string().contains("missing key"), "{err}");
        std::fs::write(&path, "not json\n").unwrap();
        assert!(Series::read_jsonl(&path, "x").is_err());
        std::fs::remove_file(&path).ok();
    }
}
