//! # SPARQ-SGD
//!
//! Production reproduction of *SPARQ-SGD: Event-Triggered and Compressed
//! Communication in Decentralized Stochastic Optimization* (Singh, Data,
//! George, Diggavi, 2019).
//!
//! The crate is the L3 coordinator of a three-layer Rust + JAX + Pallas
//! stack (see `DESIGN.md`):
//!
//! * [`coordinator`] — Algorithm 1 (SPARQ-SGD) plus the CHOCO-SGD and
//!   vanilla decentralized-SGD baselines, driven synchronously over a
//!   simulated communication graph.
//! * [`compress`] — the paper's compression operators (TopK, RandK, Sign,
//!   QSGD, composed SignTopK/QsgdTopK) with exact transmitted-bit
//!   accounting.
//! * [`trigger`] — event-triggered communication: threshold schedules
//!   `c_t` and the firing rule `‖x^{t+½} − x̂‖² > c_t η_t²`.
//! * [`graph`] — topologies, doubly-stochastic mixing matrices, spectral
//!   gap δ and the Lemma-6 consensus step size γ*.
//! * [`runtime`] — PJRT CPU client that loads the AOT HLO artifacts
//!   produced by `python/compile/aot.py` (L2 JAX models embedding the L1
//!   Pallas kernels). Python never runs on the training path.
//! * [`problems`] — gradient sources: native Rust problems (quadratic,
//!   logistic regression) for tests/benches, and artifact-backed models.
//! * [`data`] — synthetic dataset generators + heterogeneous partitioner.
//! * [`experiments`] — drivers regenerating the paper's Figure 1a–1d and
//!   the communication-savings table, expressed as declarative specs
//!   over the sweep engine.
//! * [`sweep`] — the declarative sweep engine: grid specs (variants ×
//!   axes over `ExperimentConfig`), concurrent run scheduling under a
//!   total worker budget, cross-run artifact caching, JSONL result
//!   streaming, and hash-keyed resume with mid-run checkpoints.
//! * [`config`] — the typed experiment surface: spec enums for every
//!   knob (parse-don't-validate, legacy strings + structured JSON),
//!   cross-field `resolve()`, one structured `ConfigError`.
//! * [`run`] — the `Run` handle: one training run as a value
//!   (step/eval/snapshot/restore + the canonical observer-driven loop
//!   all runners share).
//! * [`serve`] — the `sparq serve` daemon: typed spec submission over a
//!   Unix/TCP socket (CRC-framed JSON), admission control, priority
//!   scheduling onto the claim/lease worker pool, live event streaming
//!   to subscribers, crash-safe exactly-once restart takeover.
//! * [`cluster`] — the real multi-process decentralized runtime: a
//!   `sparq cluster` launcher spawns one OS process per node; processes
//!   exchange the `comm::wire` sparse codecs as CRC-framed messages
//!   over UDS/TCP behind the engine's transport seam, with claim-lease
//!   membership, real `SIGKILL` crash windows, and checkpoint-restore
//!   rejoin — lockstep runs are bit-identical to the in-process engine.
//! * [`util`] — offline-environment substrates: deterministic RNG, JSON,
//!   CLI parsing, stats, bench harness helpers.

pub mod util;
pub mod linalg;
pub mod graph;
pub mod compress;
pub mod trigger;
pub mod schedule;
pub mod comm;
pub mod data;
pub mod problems;
pub mod coordinator;
pub mod metrics;
pub mod config;
pub mod run;
pub mod experiments;
pub mod sweep;
pub mod serve;
pub mod cluster;
pub mod runtime;

/// Crate version (mirrors Cargo.toml).
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
