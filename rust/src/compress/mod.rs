//! Compression operators (paper Definition 1 and the Section-2 catalogue).
//!
//! A compression operator C satisfies E‖x − C(x)‖² ≤ (1 − ω)‖x‖² for some
//! ω ∈ (0, 1]. Implemented here, each with its contract parameter and its
//! exact transmitted-bit cost (what `comm::Bus` charges per message):
//!
//! | operator  | ω                     | bits per message                    |
//! |-----------|-----------------------|-------------------------------------|
//! | Identity  | 1                     | 32·d                                |
//! | TopK      | k/d                   | k·(32 + ⌈log₂ d⌉)                   |
//! | RandK     | k/d                   | k·32 + 64 (prng seed)               |
//! | Sign (ℓ1) | ‖x‖₁²/(d‖x‖₂²) ≥ 1/d  | d + 32                              |
//! | QSGD(s)   | 1 − min(d/s², √d/s)   | d·⌈log₂(2s+1)⌉ + 32                 |
//! | SignTopK  | ≥ 1/d ([BDKD19] (v))  | k·(1 + ⌈log₂ d⌉) + 32               |
//! | QsgdTopK  | k/(d(1+β_{k,s}))      | k·(⌈log₂(2s+1)⌉ + ⌈log₂ d⌉) + 32    |
//!
//! All operators produce the *decompressed dense vector* (what the receiver
//! reconstructs); the bit cost is tracked separately so the simulated
//! experiments charge exactly what a wire format would.

pub mod ops;
pub mod composed;
pub mod sparse;

pub use composed::{QsgdTopK, SignTopK};
pub use ops::{Identity, QsgdOp, RandK, SignL1, TopK};
pub use sparse::SparseVec;

use crate::util::Rng;

/// A compression operator (Definition 1). Implementations must be
/// deterministic given the RNG state so whole runs replay bit-for-bit.
pub trait Compressor: Send + Sync {
    /// Human-readable name used in configs/metrics (e.g. "sign_topk(k=10)").
    fn name(&self) -> String;

    /// Contract parameter ω ∈ (0, 1] for dimension d (worst-case bound).
    fn omega(&self, d: usize) -> f64;

    /// Compress `x` into `out` (dense reconstruction), drawing any internal
    /// randomness from `rng`.
    fn compress(&self, x: &[f32], rng: &mut Rng, out: &mut [f32]);

    /// Exact transmitted bits for one message of dimension d.
    fn encoded_bits(&self, d: usize) -> u64;

    /// Compress `x` directly into sparse (index, value) form — the hot-path
    /// entry point. Must densify to *exactly* what [`compress`] writes
    /// given the same RNG state (property-tested). The default runs the
    /// dense path into a thread-local scratch (no per-call allocation on
    /// the hot path) and gathers nonzeros — correct for every operator;
    /// the k-sparse operators (TopK, SignTopK, QsgdTopK) override it to
    /// skip the dense materialization entirely, and the dense operators
    /// (Identity, Sign, QSGD, RandK) keep the passthrough.
    fn compress_sparse(&self, x: &[f32], rng: &mut Rng, out: &mut SparseVec) {
        DENSE_SCRATCH.with(|cell| {
            let mut dense = cell.borrow_mut();
            // every `compress` impl fully overwrites its output buffer,
            // so resizing without clearing is safe
            dense.resize(x.len(), 0.0);
            self.compress(x, rng, &mut dense[..]);
            out.set_from_dense(&dense[..]);
        });
    }

    /// Exact wire bits for one *specific* message with `nnz` stored
    /// nonzeros at dimension d — what the bus charges on the hot path.
    /// For operators with a `comm::wire` codec (TopK, SignTopK) this
    /// matches the encoded byte length of that exact message (magnitude
    /// ties can only select *more* than k coordinates, so per-message
    /// charges are never below [`encoded_bits`]). Operators whose wire
    /// format has a fixed slot count — the dense ones, and QsgdTopK where
    /// stochastic rounding zeroes slots that must still be transmitted as
    /// level-0 symbols for the fixed-k decode protocol — keep the default,
    /// which ignores `nnz` and charges the nominal cost.
    fn message_bits(&self, d: usize, _nnz: usize) -> u64 {
        self.encoded_bits(d)
    }

    /// Typical-case compression quality used to *tune* the consensus step
    /// size (the worst-case contract ω of [`omega`] can be orders of
    /// magnitude pessimistic — e.g. SignTopK guarantees only 1/d but
    /// empirically retains ≈ k/(2d) of the energy on dense gradients; the
    /// paper's experiments, like CHOCO-SGD's, use a tuned γ).
    fn effective_omega(&self, d: usize) -> f64 {
        self.omega(d)
    }

    /// Convenience allocating wrapper.
    fn compress_vec(&self, x: &[f32], rng: &mut Rng) -> Vec<f32> {
        let mut out = vec![0.0f32; x.len()];
        self.compress(x, rng, &mut out);
        out
    }
}

/// ⌈log₂ d⌉ with log₂(1) = 1 floor (an index always costs ≥ 1 bit).
pub fn index_bits(d: usize) -> u64 {
    let mut bits = 64 - (d.max(2) as u64 - 1).leading_zeros() as u64;
    if bits == 0 {
        bits = 1;
    }
    bits
}

/// Parse an operator spec string: `identity`, `topk:K`, `randk:K`, `sign`,
/// `qsgd:S`, `sign_topk:K[:paper]`, `qsgd_topk:K:S`. K may be suffixed
/// with `%` for a fraction of d resolved at construction (`pct` helpers).
///
/// The grammar lives in [`crate::config::CompressorSpec`] (the typed
/// config surface); this is the legacy `Option` facade over it.
pub fn parse(spec: &str, d: usize) -> Option<Box<dyn Compressor>> {
    spec.parse::<crate::config::CompressorSpec>()
        .ok()
        .map(|s| s.build(d))
}

thread_local! {
    /// Scratch for magnitude selection: compression runs once per node per
    /// sync round over the full parameter vector, so the O(d) buffer is
    /// reused instead of reallocated (EXPERIMENTS.md §Perf, L3 iteration 2).
    static TOPK_SCRATCH: std::cell::RefCell<Vec<u32>> = const { std::cell::RefCell::new(Vec::new()) };

    /// Dense scratch for the default `compress_sparse` fallback (dense
    /// operators), keeping the per-round hot path allocation-free. Pool
    /// workers each get their own copy, preserving determinism.
    static DENSE_SCRATCH: std::cell::RefCell<Vec<f32>> = const { std::cell::RefCell::new(Vec::new()) };
}

/// The k-th largest |x_i| (threshold semantics; ties select the whole tie
/// class — matches the L1/L2 Pallas + ref.py semantics exactly).
///
/// O(d) quickselect over the *bit patterns* of |x_i|: for non-negative
/// IEEE-754 floats the u32 representation is order-isomorphic to the
/// value, so `select_nth_unstable` runs with integer comparisons instead
/// of a branchy `partial_cmp` closure — ~2× faster at the MLP scale
/// (EXPERIMENTS.md §Perf, L3 iteration 3).
///
/// Edge-case contract (hardened for untrusted/divergent inputs):
/// - empty input returns 0.0 (no coordinates, no threshold);
/// - NaN coordinates are treated as zero magnitude, so they can never win
///   the selection or poison the threshold. A NaN τ would make
///   `|x_i| >= τ` false everywhere and silently drop the whole message;
///   under this rule the finite coordinates still transmit and the NaN
///   ones are withheld (`NaN >= τ` is false in every selection pass, so
///   dense and sparse paths agree bit-for-bit).
pub fn topk_threshold(x: &[f32], k: usize) -> f32 {
    let d = x.len();
    if d == 0 {
        return 0.0; // clamp(1, 0) would panic; there is nothing to select
    }
    let k = k.clamp(1, d);
    // |x| clears the sign bit; the remaining bits compare like magnitudes
    // for every finite value and ±inf. NaN payloads sit *above* the inf
    // bit pattern, so map them to zero magnitude instead.
    const INF_BITS: u32 = 0x7F80_0000;
    TOPK_SCRATCH.with(|cell| {
        let mut mags = cell.borrow_mut();
        mags.clear();
        mags.extend(x.iter().map(|v| {
            let b = v.to_bits() & 0x7FFF_FFFF;
            if b > INF_BITS {
                0
            } else {
                b
            }
        }));
        let (_, tau, _) = mags.select_nth_unstable(d - k);
        f32::from_bits(*tau)
    })
}

/// Select the indices of the k largest-|x| entries *as a threshold set*:
/// returns (tau, indices of {i : |x_i| >= tau}).
pub fn topk_threshold_select(x: &[f32], k: usize) -> (f32, Vec<usize>) {
    let tau = topk_threshold(x, k);
    let idx: Vec<usize> = (0..x.len()).filter(|&i| x[i].abs() >= tau).collect();
    (tau, idx)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn index_bits_values() {
        assert_eq!(index_bits(2), 1);
        assert_eq!(index_bits(1024), 10);
        assert_eq!(index_bits(1025), 11);
        assert_eq!(index_bits(7850), 13);
    }

    #[test]
    fn parse_specs() {
        assert_eq!(parse("identity", 100).unwrap().name(), "identity");
        assert_eq!(parse("topk:10", 100).unwrap().name(), "topk(k=10)");
        assert_eq!(parse("topk:10%", 200).unwrap().name(), "topk(k=20)");
        assert_eq!(parse("sign", 10).unwrap().name(), "sign");
        assert_eq!(parse("qsgd:16", 10).unwrap().name(), "qsgd(s=16)");
        assert_eq!(
            parse("sign_topk:10", 7850).unwrap().name(),
            "sign_topk(k=10)"
        );
        assert_eq!(
            parse("qsgd_topk:5:4", 100).unwrap().name(),
            "qsgd_topk(k=5,s=4)"
        );
        assert!(parse("nope", 10).is_none());
    }

    #[test]
    fn threshold_select_counts() {
        let x = vec![0.1, -3.0, 2.0, 0.5, -0.2];
        let (tau, idx) = topk_threshold_select(&x, 2);
        assert_eq!(tau, 2.0);
        assert_eq!(idx, vec![1, 2]);
    }

    #[test]
    fn threshold_select_ties() {
        let x = vec![1.0f32, -1.0, 1.0, 0.5];
        let (tau, idx) = topk_threshold_select(&x, 2);
        assert_eq!(tau, 1.0);
        assert_eq!(idx, vec![0, 1, 2]); // whole tie class
    }

    #[test]
    fn threshold_select_zero_vector() {
        let x = vec![0.0f32; 8];
        let (tau, idx) = topk_threshold_select(&x, 3);
        assert_eq!(tau, 0.0);
        assert_eq!(idx.len(), 8);
    }

    #[test]
    fn threshold_empty_input_returns_zero() {
        // Regression: `k.clamp(1, 0)` used to hit clamp's min > max panic.
        assert_eq!(topk_threshold(&[], 3), 0.0);
        let (tau, idx) = topk_threshold_select(&[], 1);
        assert_eq!(tau, 0.0);
        assert!(idx.is_empty());
    }

    #[test]
    fn threshold_nan_never_wins_selection() {
        // Regression: a single NaN used to win the bit-pattern selection
        // (NaN payloads order above +inf), making τ NaN and the message
        // empty. Under the documented rule NaN has zero magnitude.
        let x = vec![f32::NAN, 3.0, -2.0, 1.0];
        let (tau, idx) = topk_threshold_select(&x, 2);
        assert_eq!(tau, 2.0);
        assert_eq!(idx, vec![1, 2]); // finite drift still flows
    }

    #[test]
    fn threshold_all_nan_is_deterministic() {
        let x = vec![f32::NAN; 4];
        let (tau, idx) = topk_threshold_select(&x, 2);
        assert_eq!(tau, 0.0);
        assert!(idx.is_empty()); // NaN is never transmitted
    }

    #[test]
    fn threshold_keeps_infinities_selectable() {
        let x = vec![f32::INFINITY, 1.0, f32::NAN];
        let (tau, idx) = topk_threshold_select(&x, 1);
        assert_eq!(tau, f32::INFINITY);
        assert_eq!(idx, vec![0]);
    }

    #[test]
    fn nan_compress_dense_sparse_bit_identical() {
        use crate::util::Rng;
        let x = vec![0.5f32, f32::NAN, -4.0, 3.0, 0.1, f32::NAN];
        let op = TopK::new(2);
        let mut rng = Rng::new(7);
        let dense = op.compress_vec(&x, &mut rng);
        let mut sv = SparseVec::new();
        let mut rng2 = Rng::new(7);
        op.compress_sparse(&x, &mut rng2, &mut sv);
        let densified = sv.to_dense(x.len());
        for (a, b) in dense.iter().zip(densified.iter()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // the two finite leaders transmit; NaN coordinates are withheld
        assert_eq!(dense.iter().filter(|v| **v != 0.0).count(), 2);
    }
}
