//! Sparse message representation for the compressed-exchange fast path.
//!
//! Top-k style operators produce k-sparse messages (k ≪ d), yet the seed
//! pipeline materialized every message as a dense d-vector and applied it
//! with O(d) loops — paying dense compute for sparse communication. A
//! [`SparseVec`] carries exactly the transmitted (index, value) pairs, so
//! the estimate-bank update `x̂ += q` and the consensus neighbor
//! accumulation run in O(nnz) instead of O(d), and the wire codecs in
//! `comm::wire` can encode it without a densify step.
//!
//! Invariants (upheld by every producer in this crate and asserted by the
//! property tests in `rust/tests/sparse_parallel.rs`):
//! * `idx` is strictly increasing (canonical order — matches the order the
//!   dense wire encoders scan a dense vector);
//! * `val` entries are nonzero (zeros are represented by absence);
//! * densifying reproduces *exactly* the dense `Compressor::compress`
//!   output for the same RNG stream.

/// A d-dimensional vector stored as its nonzero (index, value) pairs.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SparseVec {
    /// Nonzero coordinate indices, strictly increasing. u32 keeps the
    /// hot-path footprint at 8 bytes/entry (d < 2³² always holds here).
    pub idx: Vec<u32>,
    /// Values at those coordinates.
    pub val: Vec<f32>,
}

impl SparseVec {
    pub fn new() -> SparseVec {
        SparseVec::default()
    }

    pub fn with_capacity(k: usize) -> SparseVec {
        SparseVec {
            idx: Vec::with_capacity(k),
            val: Vec::with_capacity(k),
        }
    }

    /// Number of stored nonzeros.
    #[inline]
    pub fn nnz(&self) -> usize {
        self.idx.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.idx.is_empty()
    }

    /// Drop all entries, keeping the allocations (scratch reuse).
    pub fn clear(&mut self) {
        self.idx.clear();
        self.val.clear();
    }

    /// Append one entry. Callers must push in increasing index order.
    #[inline]
    pub fn push(&mut self, i: u32, v: f32) {
        debug_assert!(self.idx.last().map_or(true, |&last| i > last));
        self.idx.push(i);
        self.val.push(v);
    }

    /// Gather the nonzeros of a dense vector (the generic densify-free
    /// fallback used by `Compressor::compress_sparse`).
    pub fn set_from_dense(&mut self, x: &[f32]) {
        self.clear();
        for (i, &v) in x.iter().enumerate() {
            if v != 0.0 {
                self.push(i as u32, v);
            }
        }
    }

    pub fn from_dense(x: &[f32]) -> SparseVec {
        let mut s = SparseVec::new();
        s.set_from_dense(x);
        s
    }

    /// Iterate (index, value) pairs.
    pub fn iter(&self) -> impl Iterator<Item = (usize, f32)> + '_ {
        self.idx
            .iter()
            .zip(self.val.iter())
            .map(|(&i, &v)| (i as usize, v))
    }

    /// Materialize as a dense vector of dimension d.
    pub fn to_dense(&self, d: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; d];
        self.add_to(&mut out);
        out
    }

    /// out[idx] += val — the O(nnz) estimate-bank update (Algorithm 1
    /// line 13).
    #[inline]
    pub fn add_to(&self, out: &mut [f32]) {
        for (&i, &v) in self.idx.iter().zip(self.val.iter()) {
            out[i as usize] += v;
        }
    }

    /// out[idx] += a · val — the O(nnz) weighted neighbor accumulation.
    #[inline]
    pub fn add_scaled_to(&self, a: f32, out: &mut [f32]) {
        for (&i, &v) in self.idx.iter().zip(self.val.iter()) {
            out[i as usize] += a * v;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_dense_roundtrip() {
        let x = vec![0.0f32, 1.5, 0.0, -2.0, 0.0, 0.25];
        let s = SparseVec::from_dense(&x);
        assert_eq!(s.nnz(), 3);
        assert_eq!(s.idx, vec![1, 3, 5]);
        assert_eq!(s.val, vec![1.5, -2.0, 0.25]);
        assert_eq!(s.to_dense(6), x);
    }

    #[test]
    fn add_and_scaled_add() {
        let s = SparseVec::from_dense(&[0.0, 2.0, 0.0, -1.0]);
        let mut acc = vec![1.0f32; 4];
        s.add_to(&mut acc);
        assert_eq!(acc, vec![1.0, 3.0, 1.0, 0.0]);
        s.add_scaled_to(0.5, &mut acc);
        assert_eq!(acc, vec![1.0, 4.0, 1.0, -0.5]);
    }

    #[test]
    fn scratch_reuse_clears() {
        let mut s = SparseVec::with_capacity(8);
        s.set_from_dense(&[1.0, 0.0]);
        assert_eq!(s.nnz(), 1);
        s.set_from_dense(&[0.0, 0.0]);
        assert!(s.is_empty());
        assert_eq!(s.to_dense(2), vec![0.0, 0.0]);
    }

    #[test]
    fn iter_yields_pairs() {
        let s = SparseVec::from_dense(&[0.0, 4.0, 0.0, 8.0]);
        let pairs: Vec<(usize, f32)> = s.iter().collect();
        assert_eq!(pairs, vec![(1, 4.0), (3, 8.0)]);
    }
}
