//! Composed operators ([BDKD19], paper Section 2 items (iv)–(v)).
//!
//! Composing a sparsifier with a quantizer compresses further than either
//! alone while remaining a valid compression operator. SignTopK is the
//! operator used in all of the paper's experiments (Section 5: "composed
//! SignTopK operator ... we take top 10% elements of each tensor and only
//! transmit the sign and norm of the result").

use super::{index_bits, topk_threshold_select, Compressor, SparseVec};
use crate::util::Rng;

/// SignTopK: on the top-k coordinates by magnitude emit
/// `scale · sign(x_i)` with `scale = ‖selected‖₁ / |selected|`; zero
/// elsewhere. Operator (v) of Section 2 with
/// ω = max{1/d, (k/d)·‖TopK(x)‖₁²/(k‖TopK(x)‖₂²)} ≥ 1/d.
///
/// Threshold semantics match the L1 Pallas kernel and `ref.sign_topk`
/// exactly (ties select the whole tie class).
pub struct SignTopK {
    pub k: usize,
    /// Charge index bits on the wire (honest accounting). The paper's
    /// Section 5 measures SignTopK as "the sign and norm of the result" —
    /// k sign bits + one scale, *without* the k·⌈log₂ d⌉ index bits (its
    /// 250×/1000×/15K× factors only reconcile under that convention).
    /// `paper_accounting()` reproduces the paper's axes; the default
    /// charges indices too. Both are exact counts of their convention.
    pub count_indices: bool,
}

impl SignTopK {
    pub fn new(k: usize) -> Self {
        assert!(k >= 1);
        SignTopK {
            k,
            count_indices: true,
        }
    }

    /// Paper-convention accounting (signs + norm only).
    pub fn paper_accounting(k: usize) -> Self {
        SignTopK {
            k,
            count_indices: false,
        }
    }
}

impl Compressor for SignTopK {
    fn name(&self) -> String {
        format!("sign_topk(k={})", self.k)
    }

    fn omega(&self, d: usize) -> f64 {
        // Worst-case guarantee from [BDKD19] (v).
        1.0 / d as f64
    }

    fn effective_omega(&self, d: usize) -> f64 {
        // Dense-gradient estimate: the selected top-k carry most of their
        // energy and sign-scaling retains about half of it.
        (0.5 * self.k.min(d) as f64 / d as f64).max(1.0 / d as f64)
    }

    fn compress(&self, x: &[f32], _rng: &mut Rng, out: &mut [f32]) {
        out.fill(0.0);
        let tau = super::topk_threshold(x, self.k);
        // single fused pass: accumulate (l1, count) over the selected set
        let (mut l1, mut cnt) = (0.0f64, 0u32);
        for &v in x {
            let a = v.abs();
            if a >= tau {
                l1 += a as f64;
                cnt += 1;
            }
        }
        if cnt == 0 {
            return;
        }
        let scale = (l1 / cnt as f64) as f32;
        if scale == 0.0 {
            return; // all-zero selection ⇒ C(0) = 0
        }
        for (o, &v) in out.iter_mut().zip(x.iter()) {
            if v.abs() >= tau {
                *o = scale * v.signum();
            }
        }
    }

    fn compress_sparse(&self, x: &[f32], _rng: &mut Rng, out: &mut SparseVec) {
        // Same selection + scale math as the dense path, but emitting only
        // the selected coordinates (O(d) scan, O(k) output — no dense
        // fill/gather). `signum` semantics match the dense path exactly,
        // including the ±scale it assigns to selected zero entries.
        out.clear();
        let tau = super::topk_threshold(x, self.k);
        let (mut l1, mut cnt) = (0.0f64, 0u32);
        for &v in x {
            let a = v.abs();
            if a >= tau {
                l1 += a as f64;
                cnt += 1;
            }
        }
        if cnt == 0 {
            return;
        }
        let scale = (l1 / cnt as f64) as f32;
        if scale == 0.0 {
            return; // all-zero selection ⇒ C(0) = 0
        }
        for (i, &v) in x.iter().enumerate() {
            if v.abs() >= tau {
                out.push(i as u32, scale * v.signum());
            }
        }
    }

    fn encoded_bits(&self, d: usize) -> u64 {
        if self.count_indices {
            // k indices + k sign bits + one f32 scale.
            self.k.min(d) as u64 * (1 + index_bits(d)) + 32
        } else {
            // paper convention: k sign bits + one f32 scale.
            self.k.min(d) as u64 + 32
        }
    }

    fn message_bits(&self, d: usize, nnz: usize) -> u64 {
        if self.count_indices {
            // Exactly what `comm::wire::encode_sign_topk` emits.
            nnz as u64 * (1 + index_bits(d)) + 32
        } else {
            nnz as u64 + 32
        }
    }
}

/// Q_s ∘ TopK with the 1/(1+β_{k,s}) damping of [BDKD19] (iv):
/// ω = 1 − k / (d (1 + β_{k,s})).
pub struct QsgdTopK {
    pub k: usize,
    pub s: u32,
}

impl QsgdTopK {
    pub fn new(k: usize, s: u32) -> Self {
        assert!(k >= 1 && s >= 1);
        QsgdTopK { k, s }
    }

    fn beta(&self) -> f64 {
        let s = self.s as f64;
        let k = self.k as f64;
        (k / (s * s)).min(k.sqrt() / s)
    }
}

impl Compressor for QsgdTopK {
    fn name(&self) -> String {
        format!("qsgd_topk(k={},s={})", self.k, self.s)
    }

    fn omega(&self, d: usize) -> f64 {
        // [BDKD19] (iv): ω = k / (d (1 + β_{k,s})).
        let k = self.k.min(d) as f64;
        k / (d as f64 * (1.0 + self.beta()))
    }

    fn compress(&self, x: &[f32], rng: &mut Rng, out: &mut [f32]) {
        out.fill(0.0);
        let (_, idx) = topk_threshold_select(x, self.k);
        // ℓ2 norm over the selected set.
        let norm = idx
            .iter()
            .map(|&i| (x[i] as f64) * (x[i] as f64))
            .sum::<f64>()
            .sqrt() as f32;
        if norm <= 0.0 {
            return;
        }
        let s = self.s as f32;
        let damp = 1.0 / (1.0 + self.beta() as f32);
        for i in idx {
            let u = rng.f32();
            let level = (s * x[i].abs() / norm + u).floor();
            out[i] = damp * norm / s * x[i].signum() * level;
        }
    }

    fn compress_sparse(&self, x: &[f32], rng: &mut Rng, out: &mut SparseVec) {
        // Draws one uniform per *selected* coordinate in index order — the
        // identical RNG stream to the dense path — but stores only the
        // entries stochastic rounding kept.
        out.clear();
        let (_, idx) = topk_threshold_select(x, self.k);
        let norm = idx
            .iter()
            .map(|&i| (x[i] as f64) * (x[i] as f64))
            .sum::<f64>()
            .sqrt() as f32;
        if norm <= 0.0 {
            return;
        }
        let s = self.s as f32;
        let damp = 1.0 / (1.0 + self.beta() as f32);
        for i in idx {
            let u = rng.f32();
            let level = (s * x[i].abs() / norm + u).floor();
            let v = damp * norm / s * x[i].signum() * level;
            if v != 0.0 {
                out.push(i as u32, v);
            }
        }
    }

    fn encoded_bits(&self, d: usize) -> u64 {
        let sym_bits = index_bits(2 * self.s as usize + 1);
        self.k.min(d) as u64 * (sym_bits + index_bits(d)) + 32
    }

    // message_bits keeps the default (nominal k slots): the fixed-k wire
    // protocol has no length field, so slots stochastic rounding zeroed
    // still transmit a level-0 symbol — charging nnz would understate.
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::vecops::{dist2, norm2_sq};

    fn randvec(seed: u64, d: usize) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        let mut v = vec![0.0; d];
        rng.fill_normal(&mut v, 1.0);
        v
    }

    #[test]
    fn sign_topk_support_and_values() {
        let x = randvec(1, 400);
        let mut rng = Rng::new(0);
        let c = SignTopK::new(40);
        let q = c.compress_vec(&x, &mut rng);
        let nz: Vec<f32> = q.iter().copied().filter(|v| *v != 0.0).collect();
        assert_eq!(nz.len(), 40);
        // single magnitude
        let mag = nz[0].abs();
        assert!(nz.iter().all(|v| (v.abs() - mag).abs() < 1e-7));
        // signs match the source on the support
        for (a, b) in x.iter().zip(q.iter()) {
            if *b != 0.0 {
                assert_eq!(a.signum(), b.signum());
            }
        }
    }

    #[test]
    fn sign_topk_contract() {
        // Definition 1 with the conservative ω = 1/d.
        for seed in 0..20 {
            let x = randvec(seed, 300);
            let mut rng = Rng::new(0);
            let q = SignTopK::new(30).compress_vec(&x, &mut rng);
            let err = dist2(&x, &q);
            let nx = norm2_sq(&x);
            assert!(err <= (1.0 - 1.0 / 300.0) * nx + 1e-6, "seed {seed}");
        }
    }

    #[test]
    fn sign_topk_zero_input() {
        let x = vec![0.0f32; 64];
        let mut rng = Rng::new(0);
        let q = SignTopK::new(8).compress_vec(&x, &mut rng);
        assert!(q.iter().all(|v| *v == 0.0), "C(0) = 0");
    }

    #[test]
    fn sign_topk_bits_paper_setting() {
        use super::super::ops::Identity;
        // Paper Section 5.1: k=10 of 7850 ⇒ 10·(1+13)+32 = 172 bits vs
        // 32·7850 = 251200 for vanilla — the ~1000× per-message factor.
        let c = SignTopK::new(10);
        assert_eq!(c.encoded_bits(7850), 10 * 14 + 32);
        assert!(Identity.encoded_bits(7850) / c.encoded_bits(7850) > 1000);
    }

    #[test]
    fn paper_accounting_bits() {
        // signs + norm only: k + 32.
        let c = SignTopK::paper_accounting(785);
        assert_eq!(c.encoded_bits(7850), 785 + 32);
        // honest accounting includes indices.
        assert_eq!(SignTopK::new(785).encoded_bits(7850), 785 * 14 + 32);
    }

    #[test]
    fn qsgd_topk_contract_in_expectation() {
        let x = randvec(3, 200);
        let c = QsgdTopK::new(20, 8);
        let mut rng = Rng::new(5);
        let reps = 300;
        let mut acc = 0.0;
        for _ in 0..reps {
            let q = c.compress_vec(&x, &mut rng);
            acc += dist2(&x, &q);
        }
        let err = acc / reps as f64;
        let nx = norm2_sq(&x);
        assert!(err <= (1.0 - c.omega(200)) * nx * 1.05 + 1e-9);
    }

    #[test]
    fn qsgd_topk_support_bounded() {
        let x = randvec(4, 150);
        let mut rng = Rng::new(6);
        let q = QsgdTopK::new(15, 8).compress_vec(&x, &mut rng);
        // stochastic rounding may zero some of the k slots but never add.
        assert!(q.iter().filter(|v| **v != 0.0).count() <= 15);
    }

    #[test]
    fn sign_topk_sparse_matches_dense() {
        use super::super::SparseVec;
        let x = randvec(7, 500);
        let c = SignTopK::new(50);
        let mut rng_a = Rng::new(0);
        let dense = c.compress_vec(&x, &mut rng_a);
        let mut q = SparseVec::new();
        let mut rng_b = Rng::new(0);
        c.compress_sparse(&x, &mut rng_b, &mut q);
        assert_eq!(q.nnz(), 50);
        assert_eq!(q.to_dense(500), dense);
        assert_eq!(c.message_bits(500, 50), c.encoded_bits(500));
        // paper accounting variant charges signs + norm only
        let p = SignTopK::paper_accounting(50);
        assert_eq!(p.message_bits(500, 50), 50 + 32);
    }

    #[test]
    fn qsgd_topk_sparse_same_rng_stream() {
        use super::super::SparseVec;
        let x = randvec(8, 200);
        let c = QsgdTopK::new(20, 8);
        // identical seeds ⇒ identical uniform draws ⇒ identical messages
        let mut rng_a = Rng::new(9);
        let dense = c.compress_vec(&x, &mut rng_a);
        let mut q = SparseVec::new();
        let mut rng_b = Rng::new(9);
        c.compress_sparse(&x, &mut rng_b, &mut q);
        assert_eq!(q.to_dense(200), dense);
        // both streams advanced identically
        assert_eq!(rng_a.next_u64(), rng_b.next_u64());
    }
}
