//! Basic compression operators: Identity, TopK, RandK, Sign(ℓ1), QSGD.

use super::{index_bits, Compressor, SparseVec};
use crate::linalg::vecops::{norm1, norm2_sq};
use crate::util::Rng;

/// No compression (vanilla decentralized SGD baseline).
pub struct Identity;

impl Compressor for Identity {
    fn name(&self) -> String {
        "identity".into()
    }

    fn omega(&self, _d: usize) -> f64 {
        1.0
    }

    fn compress(&self, x: &[f32], _rng: &mut Rng, out: &mut [f32]) {
        out.copy_from_slice(x);
    }

    fn encoded_bits(&self, d: usize) -> u64 {
        32 * d as u64
    }
}

/// Top-k magnitude sparsifier, ω = k/d ([SCJ18]).
///
/// Threshold semantics identical to the Pallas kernel (ties keep the whole
/// tie class) — see `compress::topk_threshold_select`.
pub struct TopK {
    pub k: usize,
}

impl TopK {
    pub fn new(k: usize) -> Self {
        assert!(k >= 1);
        TopK { k }
    }
}

impl Compressor for TopK {
    fn name(&self) -> String {
        format!("topk(k={})", self.k)
    }

    fn omega(&self, d: usize) -> f64 {
        (self.k as f64 / d as f64).min(1.0)
    }

    fn compress(&self, x: &[f32], _rng: &mut Rng, out: &mut [f32]) {
        out.fill(0.0);
        let tau = super::topk_threshold(x, self.k);
        for (o, &v) in out.iter_mut().zip(x.iter()) {
            if v.abs() >= tau {
                *o = v;
            }
        }
    }

    fn compress_sparse(&self, x: &[f32], _rng: &mut Rng, out: &mut SparseVec) {
        // One selection pass, no dense output fill: emit exactly the
        // coordinates the dense path keeps, in index order.
        out.clear();
        let tau = super::topk_threshold(x, self.k);
        for (i, &v) in x.iter().enumerate() {
            if v.abs() >= tau && v != 0.0 {
                out.push(i as u32, v);
            }
        }
    }

    fn encoded_bits(&self, d: usize) -> u64 {
        // k (value, index) pairs.
        self.k.min(d) as u64 * (32 + index_bits(d))
    }

    fn message_bits(&self, d: usize, nnz: usize) -> u64 {
        // Exactly what `comm::wire::encode_topk` emits for this message.
        nnz as u64 * (32 + index_bits(d))
    }
}

/// Random-k sparsifier, ω = k/d in expectation ([SCJ18]).
///
/// Receiver can regenerate the index set from a shared 64-bit seed, so the
/// wire cost is k values + the seed.
pub struct RandK {
    pub k: usize,
}

impl RandK {
    pub fn new(k: usize) -> Self {
        assert!(k >= 1);
        RandK { k }
    }
}

impl Compressor for RandK {
    fn name(&self) -> String {
        format!("randk(k={})", self.k)
    }

    fn omega(&self, d: usize) -> f64 {
        (self.k as f64 / d as f64).min(1.0)
    }

    fn compress(&self, x: &[f32], rng: &mut Rng, out: &mut [f32]) {
        out.fill(0.0);
        let k = self.k.min(x.len());
        for i in rng.sample_indices(x.len(), k) {
            out[i] = x[i];
        }
    }

    fn encoded_bits(&self, d: usize) -> u64 {
        self.k.min(d) as u64 * 32 + 64
    }
}

/// Deterministic ℓ1-scaled sign quantizer (‖x‖₁/d)·Sign(x) of [KRSJ19],
/// ω = ‖x‖₁²/(d‖x‖₂²) ≥ 1/d.
pub struct SignL1;

impl Compressor for SignL1 {
    fn name(&self) -> String {
        "sign".into()
    }

    fn omega(&self, d: usize) -> f64 {
        // Worst-case over x (1-sparse vectors): 1/d.
        1.0 / d as f64
    }

    fn effective_omega(&self, _d: usize) -> f64 {
        // Gaussian-vector value of ‖x‖₁²/(d‖x‖₂²) → 2/π.
        2.0 / std::f64::consts::PI
    }

    fn compress(&self, x: &[f32], _rng: &mut Rng, out: &mut [f32]) {
        let d = x.len();
        let scale = (norm1(x) / d as f64) as f32;
        for (o, &v) in out.iter_mut().zip(x.iter()) {
            // sign(0) = 0 would break the two-valued wire format; the
            // payload transmits a bit per coordinate, so encode 0 as +.
            *o = if v < 0.0 { -scale } else { scale };
        }
    }

    fn encoded_bits(&self, d: usize) -> u64 {
        d as u64 + 32
    }
}

/// QSGD stochastic quantizer Q_s of [AGL+17]: unbiased, second-moment
/// bound β_{d,s} = min(d/s², √d/s); ω = 1 − β for β < 1
/// (as a *compression operator* it needs the 1/(1+β) damping when β ≥ 1;
/// we keep s large enough in configs that β < 1).
pub struct QsgdOp {
    pub s: u32,
}

impl QsgdOp {
    pub fn new(s: u32) -> Self {
        assert!(s >= 1);
        QsgdOp { s }
    }

    pub fn beta(&self, d: usize) -> f64 {
        let s = self.s as f64;
        (d as f64 / (s * s)).min((d as f64).sqrt() / s)
    }

    /// Quantize with external uniforms for cross-layer equivalence tests.
    pub fn compress_with_uniforms(&self, x: &[f32], u: &[f32], out: &mut [f32]) {
        let norm = norm2_sq(x).sqrt() as f32;
        if norm <= 0.0 {
            out.fill(0.0);
            return;
        }
        let s = self.s as f32;
        for ((o, &v), &ui) in out.iter_mut().zip(x.iter()).zip(u.iter()) {
            let level = (s * v.abs() / norm + ui).floor();
            *o = norm / s * v.signum() * level;
        }
    }
}

impl Compressor for QsgdOp {
    fn name(&self) -> String {
        format!("qsgd(s={})", self.s)
    }

    fn omega(&self, d: usize) -> f64 {
        let beta = self.beta(d);
        if beta < 1.0 {
            1.0 - beta
        } else {
            // damped variant Q_s/(1+β): ω = 1/(1+β)·(1 − β/(1+β)) — keep a
            // conservative positive value.
            1.0 / (1.0 + beta)
        }
    }

    fn compress(&self, x: &[f32], rng: &mut Rng, out: &mut [f32]) {
        // Stream the uniforms through the quantization loop instead of
        // collecting a Vec<f32> per call (this runs once per fired node
        // per sync round). Same arithmetic, same one-draw-per-coordinate
        // RNG stream as `compress_with_uniforms` with pre-drawn uniforms
        // — including the zero-norm early-out, which must still consume
        // its d draws to leave the node's RNG where the allocating
        // implementation left it.
        let norm = norm2_sq(x).sqrt() as f32;
        if norm <= 0.0 {
            for _ in 0..x.len() {
                rng.f32();
            }
            out.fill(0.0);
            return;
        }
        let s = self.s as f32;
        for (o, &v) in out.iter_mut().zip(x.iter()) {
            let u = rng.f32();
            let level = (s * v.abs() / norm + u).floor();
            *o = norm / s * v.signum() * level;
        }
    }

    fn encoded_bits(&self, d: usize) -> u64 {
        // level ∈ {0..s} plus sign ⇒ 2s+1 symbols per coordinate + norm.
        let sym_bits = index_bits(2 * self.s as usize + 1);
        d as u64 * sym_bits + 32
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::vecops::dist2;

    fn randvec(seed: u64, d: usize) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        let mut v = vec![0.0; d];
        rng.fill_normal(&mut v, 1.0);
        v
    }

    fn contract_holds(c: &dyn Compressor, x: &[f32], seed: u64) -> bool {
        // For deterministic ops one draw suffices; for stochastic ops
        // average over draws (expectation in Definition 1).
        let reps = 200;
        let mut rng = Rng::new(seed);
        let mut acc = 0.0;
        for _ in 0..reps {
            let q = c.compress_vec(x, &mut rng);
            acc += dist2(x, &q);
        }
        let err = acc / reps as f64;
        let nx = norm2_sq(x);
        err <= (1.0 - c.omega(x.len())) * nx * 1.02 + 1e-9
    }

    #[test]
    fn identity_exact() {
        let x = randvec(1, 100);
        let mut rng = Rng::new(0);
        let q = Identity.compress_vec(&x, &mut rng);
        assert_eq!(q, x);
        assert_eq!(Identity.encoded_bits(100), 3200);
    }

    #[test]
    fn topk_contract_and_support() {
        let x = randvec(2, 500);
        let c = TopK::new(50);
        let mut rng = Rng::new(0);
        let q = c.compress_vec(&x, &mut rng);
        assert_eq!(q.iter().filter(|v| **v != 0.0).count(), 50);
        assert!(contract_holds(&c, &x, 3));
        // kept entries are exact copies
        for (a, b) in x.iter().zip(q.iter()) {
            assert!(*b == 0.0 || a == b);
        }
    }

    #[test]
    fn topk_keeps_largest() {
        let x = vec![0.1f32, 5.0, -3.0, 0.2];
        let mut rng = Rng::new(0);
        let q = TopK::new(2).compress_vec(&x, &mut rng);
        assert_eq!(q, vec![0.0, 5.0, -3.0, 0.0]);
    }

    #[test]
    fn randk_contract_in_expectation() {
        let x = randvec(4, 300);
        assert!(contract_holds(&RandK::new(30), &x, 5));
    }

    #[test]
    fn randk_support_size() {
        let x = randvec(6, 100);
        let mut rng = Rng::new(7);
        let q = RandK::new(10).compress_vec(&x, &mut rng);
        assert_eq!(q.iter().filter(|v| **v != 0.0).count(), 10);
    }

    #[test]
    fn sign_contract() {
        let x = randvec(8, 200);
        assert!(contract_holds(&SignL1, &x, 9));
    }

    #[test]
    fn sign_two_valued() {
        let x = randvec(10, 64);
        let mut rng = Rng::new(0);
        let q = SignL1.compress_vec(&x, &mut rng);
        let scale = (norm1(&x) / 64.0) as f32;
        for (a, b) in x.iter().zip(q.iter()) {
            assert_eq!(*b, if *a < 0.0 { -scale } else { scale });
        }
    }

    #[test]
    fn qsgd_contract() {
        let x = randvec(12, 100);
        // s=32 ⇒ β = min(100/1024, 10/32) ≈ 0.098 < 1.
        assert!(contract_holds(&QsgdOp::new(32), &x, 13));
    }

    #[test]
    fn qsgd_unbiased() {
        let x = randvec(14, 50);
        let c = QsgdOp::new(8);
        let mut rng = Rng::new(15);
        let reps = 3000;
        let mut acc = vec![0.0f64; 50];
        for _ in 0..reps {
            let q = c.compress_vec(&x, &mut rng);
            for (a, b) in acc.iter_mut().zip(q.iter()) {
                *a += *b as f64;
            }
        }
        let norm = norm2_sq(&x).sqrt();
        let se = norm / 8.0 / (reps as f64).sqrt();
        for (a, b) in acc.iter().zip(x.iter()) {
            assert!((a / reps as f64 - *b as f64).abs() < 6.0 * se + 1e-6);
        }
    }

    #[test]
    fn qsgd_zero_vector() {
        let x = vec![0.0f32; 16];
        let mut rng = Rng::new(0);
        let q = QsgdOp::new(4).compress_vec(&x, &mut rng);
        assert!(q.iter().all(|v| *v == 0.0));
    }

    #[test]
    fn qsgd_streamed_matches_with_uniforms() {
        // The streaming compress must be the same function as
        // compress_with_uniforms fed the same RNG stream — bit-for-bit.
        let c = QsgdOp::new(8);
        for (seed, d) in [(3u64, 1usize), (4, 7), (5, 64), (6, 333)] {
            let x = randvec(seed, d);
            let mut rng_a = Rng::new(99 + seed);
            let mut out_a = vec![0.0f32; d];
            c.compress(&x, &mut rng_a, &mut out_a);
            let mut rng_b = Rng::new(99 + seed);
            let u: Vec<f32> = (0..d).map(|_| rng_b.f32()).collect();
            let mut out_b = vec![0.0f32; d];
            c.compress_with_uniforms(&x, &u, &mut out_b);
            assert_eq!(out_a, out_b, "seed {seed} d {d}");
        }
    }

    #[test]
    fn qsgd_zero_vector_consumes_same_rng_stream() {
        // The zero-norm early-out must leave the node RNG exactly where
        // the draw-then-quantize implementation left it (d draws), so a
        // run that hits a zero diff stays replay-identical.
        let d = 24;
        let c = QsgdOp::new(4);
        let mut rng = Rng::new(42);
        let mut out = vec![1.0f32; d];
        c.compress(&vec![0.0f32; d], &mut rng, &mut out);
        assert!(out.iter().all(|v| *v == 0.0));
        let mut control = Rng::new(42);
        for _ in 0..d {
            control.f32();
        }
        assert_eq!(rng.next_u64(), control.next_u64());
    }

    #[test]
    fn bit_costs() {
        assert_eq!(TopK::new(10).encoded_bits(7850), 10 * (32 + 13));
        assert_eq!(SignL1.encoded_bits(7850), 7850 + 32);
        assert_eq!(RandK::new(10).encoded_bits(1000), 320 + 64);
        // 2s+1 = 33 symbols ⇒ 6 bits
        assert_eq!(QsgdOp::new(16).encoded_bits(100), 100 * 6 + 32);
    }

    #[test]
    fn topk_sparse_matches_dense() {
        use super::super::SparseVec;
        let x = randvec(20, 300);
        let c = TopK::new(25);
        let mut rng_a = Rng::new(0);
        let dense = c.compress_vec(&x, &mut rng_a);
        let mut q = SparseVec::new();
        let mut rng_b = Rng::new(0);
        c.compress_sparse(&x, &mut rng_b, &mut q);
        assert_eq!(q.nnz(), 25);
        assert_eq!(q.to_dense(300), dense);
        assert_eq!(c.message_bits(300, q.nnz()), c.encoded_bits(300));
    }

    #[test]
    fn dense_ops_sparse_fallback_matches() {
        use super::super::SparseVec;
        let x = randvec(21, 128);
        for op in [
            Box::new(Identity) as Box<dyn Compressor>,
            Box::new(SignL1),
            Box::new(QsgdOp::new(8)),
            Box::new(RandK::new(13)),
        ] {
            let mut rng_a = Rng::new(5);
            let dense = op.compress_vec(&x, &mut rng_a);
            let mut q = SparseVec::new();
            let mut rng_b = Rng::new(5);
            op.compress_sparse(&x, &mut rng_b, &mut q);
            assert_eq!(q.to_dense(128), dense, "{}", op.name());
            // dense wire formats charge independently of stored nonzeros
            assert_eq!(
                op.message_bits(128, q.nnz()),
                op.encoded_bits(128),
                "{}",
                op.name()
            );
        }
    }
}
