//! Heterogeneous strongly-convex quadratics with known optimum.
//!
//! Node i owns f_i(x) = ½ (x − t_i)ᵀ A_i (x − t_i) with diagonal
//! A_i ∈ [μ, L]^d and node-specific targets t_i (heterogeneity). The
//! global objective f = (1/n) Σ f_i is μ-strongly convex, L-smooth, and
//! its minimizer solves (Σ A_i) x* = Σ A_i t_i — computable in closed
//! form, which is what the convergence/rate tests assert against
//! (Theorem 1's O(1/nT) behaviour and the H/c₀/ω/δ higher-order terms).
//!
//! Stochastic gradients add N(0, σ²) noise per coordinate, giving the
//! bounded-variance assumption σ̄² exactly.

use super::GradientSource;
use crate::util::Rng;

#[derive(Clone, Debug)]
pub struct QuadraticProblem {
    pub d: usize,
    pub n: usize,
    pub mu: f64,
    pub l_smooth: f64,
    pub noise_sigma: f32,
    /// Diagonal A_i, [n × d].
    a: Vec<f32>,
    /// Targets t_i, [n × d].
    t: Vec<f32>,
    /// Closed-form global optimum.
    x_star: Vec<f32>,
    f_star: f64,
}

impl QuadraticProblem {
    /// `spread` scales the per-node target offsets (data heterogeneity).
    pub fn new(d: usize, n: usize, mu: f64, l_smooth: f64, noise_sigma: f32,
               spread: f32, seed: u64) -> Self {
        assert!(mu > 0.0 && l_smooth >= mu);
        let mut rng = Rng::new(seed ^ 0x0_4A_D);
        let mut a = vec![0.0f32; n * d];
        let mut t = vec![0.0f32; n * d];
        for v in a.iter_mut() {
            *v = (mu + (l_smooth - mu) * rng.f64()) as f32;
        }
        for v in t.iter_mut() {
            *v = rng.normal_f32() * spread;
        }
        // x*_j = Σ_i a_ij t_ij / Σ_i a_ij  (diagonal system)
        let mut x_star = vec![0.0f32; d];
        for j in 0..d {
            let (mut num, mut den) = (0.0f64, 0.0f64);
            for i in 0..n {
                let aij = a[i * d + j] as f64;
                num += aij * t[i * d + j] as f64;
                den += aij;
            }
            x_star[j] = (num / den) as f32;
        }
        let mut p = QuadraticProblem {
            d,
            n,
            mu,
            l_smooth,
            noise_sigma,
            a,
            t,
            x_star,
            f_star: 0.0,
        };
        p.f_star = p.loss_at(&p.x_star.clone());
        p
    }

    fn loss_at(&self, x: &[f32]) -> f64 {
        let mut acc = 0.0f64;
        for i in 0..self.n {
            for j in 0..self.d {
                let diff = (x[j] - self.t[i * self.d + j]) as f64;
                acc += 0.5 * self.a[i * self.d + j] as f64 * diff * diff;
            }
        }
        acc / self.n as f64
    }

    pub fn x_star(&self) -> &[f32] {
        &self.x_star
    }

    pub fn f_star(&self) -> f64 {
        self.f_star
    }

    /// Suboptimality f(x) − f*.
    pub fn suboptimality(&self, x: &[f32]) -> f64 {
        self.loss_at(x) - self.f_star
    }

    /// The gradient evaluation itself is pure in the problem state (only
    /// `rng` advances), so both [`GradientSource::grad`] and the
    /// concurrent [`GradientSource::grad_shared`] route here.
    fn grad_at(&self, node: usize, x: &[f32], rng: &mut Rng, out: &mut [f32]) -> f64 {
        let base = node * self.d;
        let mut loss = 0.0f64;
        for j in 0..self.d {
            let aij = self.a[base + j];
            let diff = x[j] - self.t[base + j];
            out[j] = aij * diff + self.noise_sigma * rng.normal_f32();
            loss += 0.5 * (aij as f64) * (diff as f64) * (diff as f64);
        }
        loss
    }
}

impl GradientSource for QuadraticProblem {
    fn dim(&self) -> usize {
        self.d
    }

    fn n_nodes(&self) -> usize {
        self.n
    }

    fn grad(&mut self, node: usize, x: &[f32], rng: &mut Rng, out: &mut [f32]) -> f64 {
        self.grad_at(node, x, rng, out)
    }

    fn shared(&self) -> Option<&(dyn GradientSource + Sync)> {
        Some(self)
    }

    fn grad_shared(&self, node: usize, x: &[f32], rng: &mut Rng, out: &mut [f32]) -> f64 {
        self.grad_at(node, x, rng, out)
    }

    fn global_loss(&mut self, x: &[f32]) -> f64 {
        self.loss_at(x)
    }

    fn opt_gap(&mut self, x: &[f32]) -> Option<f64> {
        Some(self.suboptimality(x))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn optimum_has_zero_mean_gradient() {
        let mut p = QuadraticProblem::new(20, 5, 0.5, 2.0, 0.0, 1.0, 1);
        let x = p.x_star().to_vec();
        let mut rng = Rng::new(0);
        let mut g = vec![0.0f32; 20];
        let mut mean = vec![0.0f64; 20];
        for i in 0..5 {
            p.grad(i, &x, &mut rng, &mut g);
            for (m, v) in mean.iter_mut().zip(g.iter()) {
                *m += *v as f64 / 5.0;
            }
        }
        for v in mean {
            assert!(v.abs() < 1e-4, "∇f(x*) component = {v}");
        }
    }

    #[test]
    fn suboptimality_nonnegative_and_zero_at_opt() {
        let p = QuadraticProblem::new(10, 4, 0.2, 1.0, 0.1, 2.0, 2);
        assert!(p.suboptimality(p.x_star()).abs() < 1e-9);
        let mut rng = Rng::new(3);
        for _ in 0..10 {
            let x: Vec<f32> = (0..10).map(|_| rng.normal_f32() * 3.0).collect();
            assert!(p.suboptimality(&x) >= -1e-9);
        }
    }

    #[test]
    fn gradient_descent_converges() {
        let mut p = QuadraticProblem::new(15, 3, 0.5, 2.0, 0.0, 1.0, 4);
        let mut x = vec![0.0f32; 15];
        let mut g = vec![0.0f32; 15];
        let mut rng = Rng::new(5);
        for _ in 0..200 {
            // full gradient = average of node gradients (noise off)
            let mut full = vec![0.0f32; 15];
            for i in 0..3 {
                p.grad(i, &x, &mut rng, &mut g);
                for (f, v) in full.iter_mut().zip(g.iter()) {
                    *f += v / 3.0;
                }
            }
            for (xj, gj) in x.iter_mut().zip(full.iter()) {
                *xj -= 0.4 * gj;
            }
        }
        assert!(p.suboptimality(&x) < 1e-6, "gap = {}", p.suboptimality(&x));
    }

    #[test]
    fn heterogeneity_matters() {
        // With spread > 0, individual node optima differ from x*.
        let mut p = QuadraticProblem::new(8, 4, 0.5, 1.5, 0.0, 2.0, 6);
        let x = p.x_star().to_vec();
        let mut rng = Rng::new(7);
        let mut g = vec![0.0f32; 8];
        let mut some_nonzero = false;
        for i in 0..4 {
            p.grad(i, &x, &mut rng, &mut g);
            if g.iter().any(|v| v.abs() > 0.05) {
                some_nonzero = true;
            }
        }
        assert!(some_nonzero, "node gradients at x* should disagree");
    }
}
