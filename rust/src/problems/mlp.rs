//! Native two-layer ReLU MLP (the Section 5.2 non-convex experiment's
//! stand-in model; see DESIGN.md §Substitutions for the ResNet-20 →
//! MLP rationale).
//!
//! Flat layout matches `python/compile/model.py::MLP_SHAPES`:
//! [W1(din×h) | b1(h) | W2(h×C) | b2(C)], softmax cross-entropy loss.
//! Dimensions are constructor arguments so benches can run scaled-down
//! configs while the artifact-backed path exercises the paper-sized
//! (3072→128→10) model.

use super::GradientSource;
use crate::data::{Dataset, Partition};
use crate::util::Rng;

#[derive(Clone, Debug)]
pub struct MlpProblem {
    pub din: usize,
    pub hidden: usize,
    pub classes: usize,
    pub batch: usize,
    partition: Partition,
    test: Dataset,
}

/// Offsets into the flat parameter vector.
struct Offsets {
    w1: usize,
    b1: usize,
    w2: usize,
    b2: usize,
    total: usize,
}

impl MlpProblem {
    pub fn new(partition: Partition, test: Dataset, hidden: usize, batch: usize) -> Self {
        MlpProblem {
            din: test.dim,
            hidden,
            classes: test.classes,
            batch,
            partition,
            test,
        }
    }

    pub fn flat_dim(din: usize, hidden: usize, classes: usize) -> usize {
        din * hidden + hidden + hidden * classes + classes
    }

    fn offsets(&self) -> Offsets {
        let w1 = 0;
        let b1 = w1 + self.din * self.hidden;
        let w2 = b1 + self.hidden;
        let b2 = w2 + self.hidden * self.classes;
        Offsets {
            w1,
            b1,
            w2,
            b2,
            total: b2 + self.classes,
        }
    }

    /// Glorot-style init matching `model.init_flat` statistics.
    pub fn init(&self, rng: &mut Rng) -> Vec<f32> {
        let o = self.offsets();
        let mut p = vec![0.0f32; o.total];
        let std1 = (2.0 / (self.din + self.hidden) as f64).sqrt() as f32;
        let std2 = (2.0 / (self.hidden + self.classes) as f64).sqrt() as f32;
        for v in p[o.w1..o.b1].iter_mut() {
            *v = rng.normal_f32() * std1;
        }
        for v in p[o.w2..o.b2].iter_mut() {
            *v = rng.normal_f32() * std2;
        }
        p
    }

    /// Forward+backward over a batch; returns mean loss, accumulates grad.
    fn grad_batch(&self, params: &[f32], xs: &[f32], ys: &[i32], out: &mut [f32]) -> f64 {
        let o = self.offsets();
        let (din, h, c) = (self.din, self.hidden, self.classes);
        let b = ys.len();
        let w1 = &params[o.w1..o.b1];
        let b1 = &params[o.b1..o.w2];
        let w2 = &params[o.w2..o.b2];
        let b2 = &params[o.b2..];
        out.fill(0.0);
        let (gw1, rest) = out.split_at_mut(o.b1);
        let (gb1, rest) = rest.split_at_mut(h);
        let (gw2, gb2) = rest.split_at_mut(h * c);

        let mut hbuf = vec![0.0f32; h];
        let mut logits = vec![0.0f64; c];
        let mut dh = vec![0.0f32; h];
        let mut loss = 0.0f64;
        let scale = 1.0 / b as f32;

        for i in 0..b {
            let row = &xs[i * din..(i + 1) * din];
            let label = ys[i] as usize;
            // ---- forward: h = relu(x W1 + b1)
            hbuf.copy_from_slice(b1);
            for (j, &xj) in row.iter().enumerate() {
                if xj == 0.0 {
                    continue;
                }
                let wrow = &w1[j * h..(j + 1) * h];
                for k in 0..h {
                    hbuf[k] += xj * wrow[k];
                }
            }
            for v in hbuf.iter_mut() {
                if *v < 0.0 {
                    *v = 0.0;
                }
            }
            // logits = h W2 + b2
            for cls in 0..c {
                logits[cls] = b2[cls] as f64;
            }
            for (k, &hk) in hbuf.iter().enumerate() {
                if hk == 0.0 {
                    continue;
                }
                let wrow = &w2[k * c..(k + 1) * c];
                for cls in 0..c {
                    logits[cls] += hk as f64 * wrow[cls] as f64;
                }
            }
            // softmax CE
            let max = logits.iter().cloned().fold(f64::MIN, f64::max);
            let mut z = 0.0;
            for l in logits.iter_mut() {
                *l = (*l - max).exp();
                z += *l;
            }
            for l in logits.iter_mut() {
                *l /= z;
            }
            loss += -(logits[label].max(1e-300)).ln();

            // ---- backward
            // dlogits = (p - onehot) / B
            dh.fill(0.0);
            for cls in 0..c {
                let dl = ((logits[cls] - if cls == label { 1.0 } else { 0.0 }) as f32) * scale;
                if dl == 0.0 {
                    continue;
                }
                gb2[cls] += dl;
                for (k, &hk) in hbuf.iter().enumerate() {
                    gw2[k * c + cls] += hk * dl;
                    dh[k] += w2[k * c + cls] * dl;
                }
            }
            // relu mask
            for (k, hk) in hbuf.iter().enumerate() {
                if *hk <= 0.0 {
                    dh[k] = 0.0;
                }
            }
            for (k, &dhk) in dh.iter().enumerate() {
                if dhk != 0.0 {
                    gb1[k] += dhk;
                }
            }
            for (j, &xj) in row.iter().enumerate() {
                if xj == 0.0 {
                    continue;
                }
                let grow = &mut gw1[j * h..(j + 1) * h];
                for k in 0..h {
                    grow[k] += xj * dh[k];
                }
            }
        }
        loss / b as f64
    }

    fn forward_loss(&self, params: &[f32], xs: &[f32], ys: &[i32]) -> (f64, usize) {
        let o = self.offsets();
        let (din, h, c) = (self.din, self.hidden, self.classes);
        let w1 = &params[o.w1..o.b1];
        let b1 = &params[o.b1..o.w2];
        let w2 = &params[o.w2..o.b2];
        let b2 = &params[o.b2..];
        let b = ys.len();
        let mut hbuf = vec![0.0f32; h];
        let mut logits = vec![0.0f64; c];
        let mut loss = 0.0;
        let mut correct = 0;
        for i in 0..b {
            let row = &xs[i * din..(i + 1) * din];
            let label = ys[i] as usize;
            hbuf.copy_from_slice(b1);
            for (j, &xj) in row.iter().enumerate() {
                if xj == 0.0 {
                    continue;
                }
                let wrow = &w1[j * h..(j + 1) * h];
                for k in 0..h {
                    hbuf[k] += xj * wrow[k];
                }
            }
            for v in hbuf.iter_mut() {
                if *v < 0.0 {
                    *v = 0.0;
                }
            }
            for cls in 0..c {
                logits[cls] = b2[cls] as f64;
            }
            for (k, &hk) in hbuf.iter().enumerate() {
                if hk == 0.0 {
                    continue;
                }
                let wrow = &w2[k * c..(k + 1) * c];
                for cls in 0..c {
                    logits[cls] += hk as f64 * wrow[cls] as f64;
                }
            }
            let max = logits.iter().cloned().fold(f64::MIN, f64::max);
            let z: f64 = logits.iter().map(|l| (l - max).exp()).sum();
            loss += z.ln() + max - logits[label];
            let pred = logits
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            if pred == label {
                correct += 1;
            }
        }
        (loss / b as f64, correct)
    }
}

impl GradientSource for MlpProblem {
    fn dim(&self) -> usize {
        Self::flat_dim(self.din, self.hidden, self.classes)
    }

    fn n_nodes(&self) -> usize {
        self.partition.n_nodes()
    }

    fn grad(&mut self, node: usize, x: &[f32], rng: &mut Rng, out: &mut [f32]) -> f64 {
        self.grad_shared(node, x, rng, out)
    }

    fn shared(&self) -> Option<&(dyn GradientSource + Sync)> {
        // Batch sampling and backprop are pure in `&self` (the batch is
        // gathered into fresh buffers), so nodes can evaluate in parallel.
        Some(self)
    }

    fn grad_shared(&self, node: usize, x: &[f32], rng: &mut Rng, out: &mut [f32]) -> f64 {
        let (xs, ys) = self.partition.batch(node, self.batch, rng);
        self.grad_batch(x, &xs, &ys, out)
    }

    fn global_loss(&mut self, x: &[f32]) -> f64 {
        self.forward_loss(x, &self.test.x, &self.test.y).0
    }

    fn test_error(&mut self, x: &[f32]) -> Option<f64> {
        let (_, correct) = self.forward_loss(x, &self.test.x, &self.test.y);
        Some(1.0 - correct as f64 / self.test.len() as f64)
    }

    fn init_params(&self, rng: &mut Rng) -> Option<Vec<f32>> {
        Some(self.init(rng))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::ClassGaussian;
    use crate::data::iid_split;

    fn problem(seed: u64) -> MlpProblem {
        let gen = ClassGaussian::new(24, 4, 2.5, seed);
        let mut rng = Rng::new(seed + 1);
        let part = iid_split(&gen, 4, 80, &mut rng);
        let test = gen.generate(200, &mut rng);
        MlpProblem::new(part, test, 16, 8)
    }

    #[test]
    fn dim_formula() {
        let p = problem(1);
        assert_eq!(p.dim(), 24 * 16 + 16 + 16 * 4 + 4);
    }

    #[test]
    fn zero_params_uniform_loss() {
        let mut p = problem(2);
        let loss = p.global_loss(&vec![0.0; p.dim()]);
        assert!((loss - (4.0f64).ln()).abs() < 1e-9);
    }

    #[test]
    fn grad_matches_finite_differences() {
        let p = problem(3);
        let d = p.dim();
        let mut rng = Rng::new(4);
        let params = p.init(&mut rng);
        let (xs, ys) = p.partition.batch(0, 8, &mut rng);
        let mut g = vec![0.0f32; d];
        p.grad_batch(&params, &xs, &ys, &mut g);
        let eps = 1e-2f32;
        let mut checked = 0;
        for idx in [0usize, 7, 100, d - 1, d - 10] {
            let mut xp = params.clone();
            xp[idx] += eps;
            let mut xm = params.clone();
            xm[idx] -= eps;
            let mut scratch = vec![0.0f32; d];
            let lp = p.grad_batch(&xp, &xs, &ys, &mut scratch);
            let lm = p.grad_batch(&xm, &xs, &ys, &mut scratch);
            let fd = (lp - lm) / (2.0 * eps as f64);
            if fd.abs() > 1e-4 {
                assert!(
                    (fd - g[idx] as f64).abs() < 5e-2 * (1.0 + fd.abs()),
                    "idx {idx}: fd {fd} vs {}",
                    g[idx]
                );
                checked += 1;
            }
        }
        assert!(checked >= 1);
    }

    #[test]
    fn sgd_reduces_loss_and_error() {
        let mut p = problem(5);
        let mut rng = Rng::new(6);
        let mut x = p.init(&mut rng);
        let mut g = vec![0.0f32; p.dim()];
        let l0 = p.global_loss(&x);
        for t in 0..600 {
            let node = t % 4;
            p.grad(node, &x, &mut rng, &mut g);
            for (xj, gj) in x.iter_mut().zip(g.iter()) {
                *xj -= 0.1 * gj;
            }
        }
        let l1 = p.global_loss(&x);
        assert!(l1 < l0 * 0.6, "loss {l0} -> {l1}");
        assert!(p.test_error(&x).unwrap() < 0.3);
    }
}
