//! Native multinomial logistic regression (the Section 5.1 convex
//! objective) over a heterogeneous `data::Partition`.
//!
//! Semantics are identical to the L2 JAX graph `model.logreg_*` (softmax
//! cross-entropy + ½λ‖x‖², flat layout [W(din×C) | b(C)]); the runtime
//! integration test checks gradient agreement against the AOT artifact to
//! float tolerance. The native path exists so the big fig-1 sweeps run at
//! memory bandwidth instead of PJRT dispatch overhead — same math, same
//! layout, interchangeable via `GradientSource`.

use super::GradientSource;
use crate::data::{Dataset, Partition};
use crate::util::Rng;

#[derive(Clone, Debug)]
pub struct LogRegProblem {
    pub din: usize,
    pub classes: usize,
    pub l2: f32,
    pub batch: usize,
    partition: Partition,
    test: Dataset,
    // scratch
    logits: Vec<f64>,
}

impl LogRegProblem {
    pub fn new(partition: Partition, test: Dataset, batch: usize, l2: f32) -> Self {
        let din = test.dim;
        let classes = test.classes;
        LogRegProblem {
            din,
            classes,
            l2,
            batch,
            partition,
            test,
            logits: vec![0.0; classes],
        }
    }

    pub fn flat_dim(din: usize, classes: usize) -> usize {
        din * classes + classes
    }

    /// logits_c = x_row · W[:,c] + b_c ; returns (loss, true-class prob
    /// vector) and leaves softmax probabilities in self.logits.
    fn forward(&mut self, params: &[f32], row: &[f32], label: usize) -> f64 {
        let c = self.classes;
        let w = &params[..self.din * c];
        let b = &params[self.din * c..];
        for cls in 0..c {
            self.logits[cls] = b[cls] as f64;
        }
        for (j, &xj) in row.iter().enumerate() {
            if xj == 0.0 {
                continue;
            }
            let wrow = &w[j * c..(j + 1) * c];
            for cls in 0..c {
                self.logits[cls] += xj as f64 * wrow[cls] as f64;
            }
        }
        let max = self.logits.iter().cloned().fold(f64::MIN, f64::max);
        let mut z = 0.0;
        for l in self.logits.iter_mut() {
            *l = (*l - max).exp();
            z += *l;
        }
        for l in self.logits.iter_mut() {
            *l /= z; // now probabilities
        }
        -(self.logits[label].max(1e-300)).ln()
    }

    /// Mini-batch loss+grad at `params` for rows (xs, ys); `out` += grad.
    fn grad_batch(&mut self, params: &[f32], xs: &[f32], ys: &[i32], out: &mut [f32]) -> f64 {
        let c = self.classes;
        let b = ys.len();
        out.fill(0.0);
        let mut loss = 0.0;
        for i in 0..b {
            let row = &xs[i * self.din..(i + 1) * self.din];
            let label = ys[i] as usize;
            loss += self.forward(params, row, label);
            // dlogits = p - onehot(label), scaled by 1/B
            let scale = 1.0 / b as f64;
            for cls in 0..c {
                let dl = (self.logits[cls] - if cls == label { 1.0 } else { 0.0 }) * scale;
                let dlf = dl as f32;
                if dlf == 0.0 {
                    continue;
                }
                // dW[j, cls] += x_j * dl ; db[cls] += dl
                for (j, &xj) in row.iter().enumerate() {
                    out[j * c + cls] += xj * dlf;
                }
                out[self.din * c + cls] += dlf;
            }
        }
        // ridge term
        if self.l2 > 0.0 {
            let mut reg = 0.0f64;
            for (o, &p) in out.iter_mut().zip(params.iter()) {
                *o += self.l2 * p;
                reg += 0.5 * self.l2 as f64 * (p as f64) * (p as f64);
            }
            loss / b as f64 + reg
        } else {
            loss / b as f64
        }
    }

    /// (mean test CE loss, test error) at `params`.
    fn eval(&mut self, params: &[f32]) -> (f64, f64) {
        let n = self.test.len();
        let mut loss = 0.0;
        let mut correct = 0usize;
        // rows are copied out so `forward` can borrow &mut self.logits
        for i in 0..n {
            let label = self.test.y[i] as usize;
            let row_start = i * self.din;
            let row: Vec<f32> = self.test.x[row_start..row_start + self.din].to_vec();
            loss += self.forward(params, &row, label);
            let pred = self
                .logits
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            if pred == label {
                correct += 1;
            }
        }
        (loss / n as f64, 1.0 - correct as f64 / n as f64)
    }
}

impl GradientSource for LogRegProblem {
    fn dim(&self) -> usize {
        Self::flat_dim(self.din, self.classes)
    }

    fn n_nodes(&self) -> usize {
        self.partition.n_nodes()
    }

    fn grad(&mut self, node: usize, x: &[f32], rng: &mut Rng, out: &mut [f32]) -> f64 {
        let (xs, ys) = self.partition.batch(node, self.batch, rng);
        self.grad_batch(x, &xs, &ys, out)
    }

    fn global_loss(&mut self, x: &[f32]) -> f64 {
        self.eval(x).0
    }

    fn test_error(&mut self, x: &[f32]) -> Option<f64> {
        Some(self.eval(x).1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::ClassGaussian;
    use crate::data::{by_class_shards, iid_split};

    fn problem(seed: u64) -> LogRegProblem {
        let gen = ClassGaussian::new(20, 4, 2.0, seed);
        let mut rng = Rng::new(seed + 1);
        let part = by_class_shards(&gen, 4, 60, 2, &mut rng);
        let test = gen.generate(200, &mut rng);
        LogRegProblem::new(part, test, 8, 1e-4)
    }

    #[test]
    fn uniform_params_give_log_c_loss() {
        let mut p = problem(1);
        let d = p.dim();
        let loss = p.global_loss(&vec![0.0; d]);
        assert!((loss - (4.0f64).ln()).abs() < 1e-6, "loss {loss}");
    }

    #[test]
    fn grad_matches_finite_differences() {
        let mut p = problem(2);
        let d = p.dim();
        let mut rng = Rng::new(3);
        let x: Vec<f32> = (0..d).map(|_| rng.normal_f32() * 0.1).collect();
        // deterministic "batch": use full local shard via repeated calls
        // with the same rng clone
        let mut g = vec![0.0f32; d];
        let mut rng_a = Rng::new(42);
        p.grad(0, &x, &mut rng_a, &mut g);
        // same batch again via same rng seed for FD evaluation
        let eps = 1e-3f32;
        for &idx in &[0usize, 5, d - 1, d - 3] {
            let mut xp = x.clone();
            xp[idx] += eps;
            let mut xm = x.clone();
            xm[idx] -= eps;
            let mut scratch = vec![0.0f32; d];
            let mut r1 = Rng::new(42);
            let lp = p.grad(0, &xp, &mut r1, &mut scratch);
            let mut r2 = Rng::new(42);
            let lm = p.grad(0, &xm, &mut r2, &mut scratch);
            let fd = (lp - lm) / (2.0 * eps as f64);
            assert!(
                (fd - g[idx] as f64).abs() < 2e-2 * (1.0 + fd.abs()),
                "idx {idx}: fd {fd} vs grad {}",
                g[idx]
            );
        }
    }

    #[test]
    fn sgd_learns_separable_data() {
        let mut p = problem(4);
        let d = p.dim();
        let mut x = vec![0.0f32; d];
        let mut g = vec![0.0f32; d];
        let mut rng = Rng::new(5);
        let e0 = p.test_error(&x).unwrap();
        for t in 0..400 {
            let node = t % 4;
            p.grad(node, &x, &mut rng, &mut g);
            for (xj, gj) in x.iter_mut().zip(g.iter()) {
                *xj -= 0.1 * gj;
            }
        }
        let e1 = p.test_error(&x).unwrap();
        assert!(e1 < e0 * 0.5, "test error {e0} -> {e1}");
    }

    #[test]
    fn iid_partition_also_works() {
        let gen = ClassGaussian::new(10, 3, 3.0, 9);
        let mut rng = Rng::new(10);
        let part = iid_split(&gen, 3, 50, &mut rng);
        let test = gen.generate(100, &mut rng);
        let mut p = LogRegProblem::new(part, test, 4, 0.0);
        assert_eq!(p.dim(), 33);
        assert_eq!(p.n_nodes(), 3);
        let mut g = vec![0.0f32; 33];
        let loss = p.grad(1, &vec![0.0; 33], &mut rng, &mut g);
        assert!((loss - (3.0f64).ln()).abs() < 1e-6);
    }
}
