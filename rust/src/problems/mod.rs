//! Gradient sources: what each node differentiates.
//!
//! The coordinator is generic over [`GradientSource`] so the same
//! Algorithm-1 implementation drives:
//!
//! * [`quadratic::QuadraticProblem`] — strongly-convex quadratics with a
//!   *known* global optimum (rate/convergence tests, Theorem-1 sanity);
//! * [`logreg::LogRegProblem`] — native multinomial logistic regression
//!   (the Section 5.1 convex experiment);
//! * [`mlp::MlpProblem`] — native two-layer ReLU network (the Section 5.2
//!   non-convex experiment);
//! * `runtime::PjrtModel` — any AOT HLO artifact (logreg / MLP /
//!   transformer LM), the production path where the L2 JAX graph (with L1
//!   Pallas kernels) does the math.

pub mod quadratic;
pub mod logreg;
pub mod mlp;

pub use logreg::LogRegProblem;
pub use mlp::MlpProblem;
pub use quadratic::QuadraticProblem;

use crate::util::Rng;

/// Per-node stochastic gradient oracle plus global metrics.
pub trait GradientSource {
    /// Parameter dimension d.
    fn dim(&self) -> usize;

    /// Number of nodes this source partitions data across.
    fn n_nodes(&self) -> usize;

    /// Stochastic gradient of f_i at x into `out`; returns the mini-batch
    /// loss. `rng` supplies the sampling randomness (ξ_i^{(t)}).
    fn grad(&mut self, node: usize, x: &[f32], rng: &mut Rng, out: &mut [f32]) -> f64;

    /// Shared-state handle enabling the coordinator's parallel gradient
    /// phase: return `Some(self)` when per-node evaluation is pure in
    /// `&self` (the `Sync` bound makes the compiler enforce
    /// thread-safety — sources with non-`Sync` internals cannot
    /// accidentally opt in). Sources that mutate internal scratch during
    /// evaluation keep the `None` default and run sequentially.
    fn shared(&self) -> Option<&(dyn GradientSource + Sync)> {
        None
    }

    /// Like [`grad`] but through a shared reference — reachable only via
    /// [`shared`]. Implementations must produce the exact same values and
    /// draw identically from `rng`, so parallel and sequential runs
    /// replay bit-for-bit.
    fn grad_shared(&self, _node: usize, _x: &[f32], _rng: &mut Rng, _out: &mut [f32]) -> f64 {
        panic!("grad_shared called on a source without shared-state support")
    }

    /// Global objective f(x) (deterministic, for metrics).
    fn global_loss(&mut self, x: &[f32]) -> f64;

    /// Test error in [0,1] if the problem has one (classification).
    fn test_error(&mut self, _x: &[f32]) -> Option<f64> {
        None
    }

    /// Distance to the known optimum, if the problem knows it.
    fn opt_gap(&mut self, _x: &[f32]) -> Option<f64> {
        None
    }

    /// Non-trivial initial parameters, if the problem needs them (e.g. an
    /// MLP at exactly zero sits on a saddle where only the output bias
    /// receives gradient). `None` ⇒ zeros.
    fn init_params(&self, _rng: &mut Rng) -> Option<Vec<f32>> {
        None
    }
}

/// Forward every trait method through a level of indirection (including
/// defaulted ones — `shared`/`grad_shared` gate the parallel gradient
/// phase and must not fall back to the trait defaults).
macro_rules! forward_gradient_source {
    () => {
        fn dim(&self) -> usize {
            (**self).dim()
        }
        fn n_nodes(&self) -> usize {
            (**self).n_nodes()
        }
        fn grad(&mut self, node: usize, x: &[f32], rng: &mut Rng, out: &mut [f32]) -> f64 {
            (**self).grad(node, x, rng, out)
        }
        fn shared(&self) -> Option<&(dyn GradientSource + Sync)> {
            (**self).shared()
        }
        fn grad_shared(&self, node: usize, x: &[f32], rng: &mut Rng, out: &mut [f32]) -> f64 {
            (**self).grad_shared(node, x, rng, out)
        }
        fn global_loss(&mut self, x: &[f32]) -> f64 {
            (**self).global_loss(x)
        }
        fn test_error(&mut self, x: &[f32]) -> Option<f64> {
            (**self).test_error(x)
        }
        fn opt_gap(&mut self, x: &[f32]) -> Option<f64> {
            (**self).opt_gap(x)
        }
        fn init_params(&self, rng: &mut Rng) -> Option<Vec<f32>> {
            (**self).init_params(rng)
        }
    };
}

/// `&mut dyn GradientSource` is itself a source (borrowed form for the
/// generic [`Run`](crate::run::Run) handle).
impl<T: GradientSource + ?Sized> GradientSource for &mut T {
    forward_gradient_source!();
}

/// `Box<dyn GradientSource>` is itself a source (owned form for
/// [`Run`](crate::run::Run)).
impl<T: GradientSource + ?Sized> GradientSource for Box<T> {
    forward_gradient_source!();
}
