//! Typed spec values for every composable knob of an experiment.
//!
//! Parse-don't-validate: each field of
//! [`ExperimentConfig`](super::ExperimentConfig) is one of these types,
//! constructed exactly once — from a legacy spec string (`FromStr`
//! accepts every pre-redesign form), a structured JSON object
//! (`{"kind": "topk", "k": 100}` alongside `"topk:100"`), or a typed
//! constructor — and guaranteed well-formed from then on. Invalid specs
//! are unrepresentable past the config boundary; code downstream matches
//! on the parsed payload instead of re-splitting strings.
//!
//! **Canonical strings.** Every spec remembers the exact string it was
//! parsed from (typed constructors and JSON objects generate one), and
//! `Display`/`to_json` emit it verbatim. `config_hash`, sweep resume
//! ids, and `results.jsonl` therefore stay bit-compatible with the
//! string-field era: parsing a legacy config and re-serializing it is
//! the identity on bytes (`rust/tests/config_golden.rs` pins this for
//! the driver specs and every `examples/specs/*.json`).
//!
//! Cross-field constraints (straggler index vs node count, `sample:B:M`
//! vs the base graph's edge count, TopK `k` vs the problem dimension, …)
//! cannot be checked by a single field; they live in
//! [`ExperimentConfig::resolve`](super::ExperimentConfig::resolve).

use std::fmt;
use std::str::FromStr;

use super::error::ConfigError;
use crate::compress::Compressor;
use crate::graph::TopologyKind;
use crate::schedule::{LrSchedule, SyncSchedule};
use crate::trigger::{EventTrigger, ThresholdSchedule};
use crate::util::json::Json;

/// Shortest-round-trip float rendering for canonical spec strings
/// (`2.0f64` renders as `"2"`, matching what a user would type).
fn fmt_f64(x: f64) -> String {
    format!("{x}")
}

/// Shared boilerplate: `Display` = canonical string, `FromStr` =
/// legacy-grammar parser, panicking `From<&str>`/`From<String>` so
/// struct-literal config construction (`compressor: "sign".into()`)
/// keeps working — with the same panic prefixes the old builders used —
/// and `PartialEq<&str>` for spec-string comparisons in tests/benches.
macro_rules! spec_common {
    ($ty:ident, $panic_prefix:literal) => {
        impl fmt::Display for $ty {
            fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
                f.write_str(&self.raw)
            }
        }

        impl FromStr for $ty {
            type Err = ConfigError;
            fn from_str(s: &str) -> Result<Self, ConfigError> {
                Self::parse_spec(s)
            }
        }

        impl From<&str> for $ty {
            fn from(s: &str) -> $ty {
                s.parse()
                    .unwrap_or_else(|e| panic!(concat!($panic_prefix, " {:?}: {}"), s, e))
            }
        }

        impl From<String> for $ty {
            fn from(s: String) -> $ty {
                $ty::from(s.as_str())
            }
        }

        impl PartialEq<&str> for $ty {
            fn eq(&self, other: &&str) -> bool {
                self.raw == *other
            }
        }

        impl PartialEq<str> for $ty {
            fn eq(&self, other: &str) -> bool {
                self.raw == other
            }
        }

        impl $ty {
            /// The canonical spec string (what `Display` and `to_json`
            /// emit).
            pub fn as_str(&self) -> &str {
                &self.raw
            }
        }
    };
}

/// The default JSON form: the canonical spec string. (`SyncSpec` opts
/// out — its legacy JSON form is a number.)
macro_rules! spec_string_json {
    ($ty:ident) => {
        impl $ty {
            /// JSON form: the canonical spec string. (Input additionally
            /// accepts a structured object — see [`Self::from_json`].)
            pub fn to_json(&self) -> Json {
                Json::Str(self.raw.clone())
            }
        }
    };
}

/// Reject unknown keys in a structured-object spec (typo safety).
fn check_obj_keys(field: &str, j: &Json, valid: &[&str]) -> Result<(), ConfigError> {
    let obj = j.as_obj().expect("caller matched Json::Obj");
    for key in obj.keys() {
        if !valid.contains(&key.as_str()) {
            return Err(ConfigError::value(
                field,
                j.to_string(),
                format!("unknown key {key:?} in spec object"),
            )
            .suggest(format!("one of: {}", valid.join(", "))));
        }
    }
    Ok(())
}

fn obj_kind(field: &str, j: &Json) -> Result<String, ConfigError> {
    j.get("kind")
        .and_then(Json::as_str)
        .map(str::to_string)
        .ok_or_else(|| {
            ConfigError::value(field, j.to_string(), "spec object needs a string \"kind\"")
        })
}

fn obj_f64(field: &str, j: &Json, key: &str) -> Result<f64, ConfigError> {
    j.get(key).and_then(Json::as_f64).ok_or_else(|| {
        ConfigError::value(field, j.to_string(), format!("missing numeric key {key:?}"))
    })
}

fn obj_u64(field: &str, j: &Json, key: &str) -> Result<u64, ConfigError> {
    let x = obj_f64(field, j, key)?;
    if !x.is_finite() || x < 0.0 || x.fract() != 0.0 {
        return Err(ConfigError::value(
            field,
            j.to_string(),
            format!("key {key:?} must be a non-negative integer, got {x}"),
        ));
    }
    Ok(x as u64)
}

// ---------------------------------------------------------------------
// CompressorSpec
// ---------------------------------------------------------------------

/// A sparsity level: an absolute coordinate count or a percentage of the
/// problem dimension, resolved at construction time.
#[derive(Clone, Debug, PartialEq)]
pub enum KSpec {
    Count(usize),
    /// Percent of d in (0, 100].
    Percent(f64),
}

impl KSpec {
    /// Resolve against dimension d (legacy semantics: round, clamp to
    /// [1, d]).
    pub fn resolve(&self, d: usize) -> usize {
        match self {
            KSpec::Count(k) => *k,
            KSpec::Percent(p) => ((p / 100.0 * d as f64).round() as usize).clamp(1, d),
        }
    }

    fn parse(field: &str, s: &str) -> Result<KSpec, ConfigError> {
        if let Some(p) = s.strip_suffix('%') {
            let frac: f64 = p.parse().map_err(|_| {
                ConfigError::value(field, s, "percentage is not a number")
            })?;
            if !frac.is_finite() || frac <= 0.0 || frac > 100.0 {
                return Err(ConfigError::value(
                    field,
                    s,
                    format!("percentage must lie in (0, 100], got {frac}"),
                ));
            }
            Ok(KSpec::Percent(frac))
        } else {
            let k: usize = s
                .parse()
                .map_err(|_| ConfigError::value(field, s, "k is not a positive integer"))?;
            if k == 0 {
                return Err(ConfigError::value(field, s, "k must be >= 1"));
            }
            Ok(KSpec::Count(k))
        }
    }
}

/// The parsed payload of a [`CompressorSpec`] (the paper's operator
/// catalogue — see `compress` module docs for contracts and bit costs).
#[derive(Clone, Debug, PartialEq)]
pub enum CompressorKind {
    Identity,
    Sign,
    TopK(KSpec),
    RandK(KSpec),
    Qsgd { s: u32 },
    SignTopK { k: KSpec, paper: bool },
    QsgdTopK { k: KSpec, s: u32 },
}

/// Typed compression-operator spec. Construct with [`FromStr`] (legacy
/// strings: `identity`, `sign`, `topk:K`, `randk:K`, `qsgd:S`,
/// `sign_topk:K[:paper]`, `qsgd_topk:K:S`, K optionally `%`-suffixed),
/// [`CompressorSpec::from_json`], or the typed constructors; build the
/// operator with [`CompressorSpec::build`].
#[derive(Clone, Debug, PartialEq)]
pub struct CompressorSpec {
    raw: String,
    kind: CompressorKind,
}

spec_string_json!(CompressorSpec);
spec_common!(CompressorSpec, "bad compressor spec");

impl CompressorSpec {
    pub fn kind(&self) -> &CompressorKind {
        &self.kind
    }

    pub fn identity() -> Self {
        "identity".parse().expect("static spec")
    }

    pub fn sign() -> Self {
        "sign".parse().expect("static spec")
    }

    pub fn top_k(k: usize) -> Self {
        format!("topk:{k}").as_str().into()
    }

    pub fn top_k_pct(pct: f64) -> Self {
        format!("topk:{}%", fmt_f64(pct)).as_str().into()
    }

    pub fn rand_k(k: usize) -> Self {
        format!("randk:{k}").as_str().into()
    }

    pub fn qsgd(s: u32) -> Self {
        format!("qsgd:{s}").as_str().into()
    }

    pub fn sign_top_k(k: usize) -> Self {
        format!("sign_topk:{k}").as_str().into()
    }

    pub fn sign_top_k_pct(pct: f64) -> Self {
        format!("sign_topk:{}%", fmt_f64(pct)).as_str().into()
    }

    pub fn qsgd_top_k(k: usize, s: u32) -> Self {
        format!("qsgd_topk:{k}:{s}").as_str().into()
    }

    /// Switch a SignTopK spec to the paper's signs+norm bit accounting
    /// (Section 5.2 convention; see `compress::SignTopK`).
    pub fn paper_accounting(self) -> Self {
        match self.kind {
            CompressorKind::SignTopK { paper: false, .. } => {
                format!("{}:paper", self.raw).as_str().into()
            }
            _ => self,
        }
    }

    /// The resolved sparsity k at dimension d, if the operator is
    /// k-sparse.
    pub fn resolved_k(&self, d: usize) -> Option<usize> {
        match &self.kind {
            CompressorKind::TopK(k)
            | CompressorKind::RandK(k)
            | CompressorKind::SignTopK { k, .. }
            | CompressorKind::QsgdTopK { k, .. } => Some(k.resolve(d)),
            _ => None,
        }
    }

    /// Instantiate the operator for dimension d (infallible: everything
    /// value-dependent was validated at parse time; cross-field k-vs-d
    /// sanity lives in `ExperimentConfig::resolve`).
    pub fn build(&self, d: usize) -> Box<dyn Compressor> {
        use crate::compress::{Identity, QsgdOp, QsgdTopK, RandK, SignL1, SignTopK, TopK};
        match &self.kind {
            CompressorKind::Identity => Box::new(Identity),
            CompressorKind::Sign => Box::new(SignL1),
            CompressorKind::TopK(k) => Box::new(TopK::new(k.resolve(d))),
            CompressorKind::RandK(k) => Box::new(RandK::new(k.resolve(d))),
            CompressorKind::Qsgd { s } => Box::new(QsgdOp::new(*s)),
            CompressorKind::SignTopK { k, paper: false } => {
                Box::new(SignTopK::new(k.resolve(d)))
            }
            CompressorKind::SignTopK { k, paper: true } => {
                Box::new(SignTopK::paper_accounting(k.resolve(d)))
            }
            CompressorKind::QsgdTopK { k, s } => Box::new(QsgdTopK::new(k.resolve(d), *s)),
        }
    }

    fn parse_spec(s: &str) -> Result<Self, ConfigError> {
        const FIELD: &str = "compressor";
        let usage = "identity, sign, topk:K, randk:K, qsgd:S, sign_topk:K[:paper], \
                     or qsgd_topk:K:S (K may be %-suffixed)";
        let qsgd_s = |v: &str| -> Result<u32, ConfigError> {
            let s: u32 = v.parse().map_err(|_| {
                ConfigError::value(FIELD, v, "quantization level S is not a positive integer")
            })?;
            if s == 0 {
                return Err(ConfigError::value(FIELD, v, "quantization level S must be >= 1"));
            }
            Ok(s)
        };
        // Sub-field rejections report the whole spec string the user
        // wrote, not just the offending fragment.
        let k_of = |k: &str| KSpec::parse(FIELD, k).map_err(|e| e.with_value(s));
        let parts: Vec<&str> = s.split(':').collect();
        let kind = match parts.as_slice() {
            ["identity"] => CompressorKind::Identity,
            ["sign"] => CompressorKind::Sign,
            ["topk", k] => CompressorKind::TopK(k_of(k)?),
            ["randk", k] => CompressorKind::RandK(k_of(k)?),
            ["qsgd", sv] => CompressorKind::Qsgd {
                s: qsgd_s(sv).map_err(|e| e.with_value(s))?,
            },
            ["sign_topk", k] => CompressorKind::SignTopK {
                k: k_of(k)?,
                paper: false,
            },
            ["sign_topk", k, "paper"] => CompressorKind::SignTopK {
                k: k_of(k)?,
                paper: true,
            },
            ["qsgd_topk", k, sv] => CompressorKind::QsgdTopK {
                k: k_of(k)?,
                s: qsgd_s(sv).map_err(|e| e.with_value(s))?,
            },
            _ => {
                return Err(ConfigError::value(FIELD, s, "unknown operator").suggest(usage));
            }
        };
        Ok(CompressorSpec {
            raw: s.to_string(),
            kind,
        })
    }

    /// Accepts the canonical string or `{"kind": ..., ...}` objects.
    pub fn from_json(j: &Json) -> Result<Self, ConfigError> {
        match j {
            Json::Str(s) => s.parse(),
            Json::Obj(_) => {
                check_obj_keys("compressor", j, &["kind", "k", "s", "paper"])?;
                let kind = obj_kind("compressor", j)?;
                let k = || -> Result<String, ConfigError> {
                    match j.get("k") {
                        Some(Json::Str(s)) => Ok(s.clone()),
                        Some(Json::Num(x)) => Ok(fmt_f64(*x)),
                        _ => Err(ConfigError::value(
                            "compressor",
                            j.to_string(),
                            "missing key \"k\" (a count, or a \"P%\" string)",
                        )),
                    }
                };
                let s_level = || obj_u64("compressor", j, "s").map(|s| s.to_string());
                let paper = j.get("paper").and_then(Json::as_bool).unwrap_or(false);
                let spec = match kind.as_str() {
                    "identity" => "identity".to_string(),
                    "sign" => "sign".to_string(),
                    "topk" => format!("topk:{}", k()?),
                    "randk" => format!("randk:{}", k()?),
                    "qsgd" => format!("qsgd:{}", s_level()?),
                    "sign_topk" if paper => format!("sign_topk:{}:paper", k()?),
                    "sign_topk" => format!("sign_topk:{}", k()?),
                    "qsgd_topk" => format!("qsgd_topk:{}:{}", k()?, s_level()?),
                    other => {
                        return Err(ConfigError::value(
                            "compressor",
                            j.to_string(),
                            format!("unknown compressor kind {other:?}"),
                        ))
                    }
                };
                spec.parse()
            }
            other => Err(ConfigError::value(
                "compressor",
                other.to_string(),
                "expected a spec string or object",
            )),
        }
    }
}

// ---------------------------------------------------------------------
// TriggerSpec
// ---------------------------------------------------------------------

/// Typed event-trigger threshold spec (`zero`, `const:C`, `poly:C0:EPS`,
/// `piecewise:INIT:STEP:EVERY:UNTIL:SPE`, or the EventGraD-style
/// per-coordinate form `percoord:C`); payload is the validated
/// [`ThresholdSchedule`] plus the per-coordinate flag.
#[derive(Clone, Debug, PartialEq)]
pub struct TriggerSpec {
    raw: String,
    sched: ThresholdSchedule,
    per_coord: bool,
}

spec_string_json!(TriggerSpec);
spec_common!(TriggerSpec, "bad trigger spec");

impl TriggerSpec {
    pub fn schedule(&self) -> &ThresholdSchedule {
        &self.sched
    }

    /// Per-coordinate (EventGraD) mode — `percoord:C` specs.
    pub fn per_coord(&self) -> bool {
        self.per_coord
    }

    /// The runnable trigger this spec describes.
    pub fn event_trigger(&self) -> EventTrigger {
        if self.per_coord {
            EventTrigger::new_per_coord(self.sched.clone())
        } else {
            EventTrigger::new(self.sched.clone())
        }
    }

    pub fn zero() -> Self {
        "zero".parse().expect("static spec")
    }

    pub fn percoord(c: f64) -> Self {
        format!("percoord:{}", fmt_f64(c)).as_str().into()
    }

    pub fn constant(c0: f64) -> Self {
        format!("const:{}", fmt_f64(c0)).as_str().into()
    }

    pub fn poly(c0: f64, eps: f64) -> Self {
        format!("poly:{}:{}", fmt_f64(c0), fmt_f64(eps)).as_str().into()
    }

    pub fn piecewise(init: f64, step: f64, every: usize, until: usize, spe: usize) -> Self {
        format!(
            "piecewise:{}:{}:{every}:{until}:{spe}",
            fmt_f64(init),
            fmt_f64(step)
        )
        .as_str()
        .into()
    }

    fn parse_spec(s: &str) -> Result<Self, ConfigError> {
        let trig = EventTrigger::parse(s)
            .map_err(|reason| ConfigError::value("trigger", s, reason))?;
        Ok(TriggerSpec {
            raw: s.to_string(),
            sched: trig.schedule,
            per_coord: trig.per_coord,
        })
    }

    pub fn from_json(j: &Json) -> Result<Self, ConfigError> {
        match j {
            Json::Str(s) => s.parse(),
            Json::Obj(_) => {
                check_obj_keys(
                    "trigger",
                    j,
                    &["kind", "c0", "eps", "init", "step", "every", "until", "steps_per_epoch"],
                )?;
                let spec = match obj_kind("trigger", j)?.as_str() {
                    "zero" => "zero".to_string(),
                    "const" => format!("const:{}", fmt_f64(obj_f64("trigger", j, "c0")?)),
                    "percoord" => format!("percoord:{}", fmt_f64(obj_f64("trigger", j, "c0")?)),
                    "poly" => format!(
                        "poly:{}:{}",
                        fmt_f64(obj_f64("trigger", j, "c0")?),
                        fmt_f64(obj_f64("trigger", j, "eps")?)
                    ),
                    "piecewise" => format!(
                        "piecewise:{}:{}:{}:{}:{}",
                        fmt_f64(obj_f64("trigger", j, "init")?),
                        fmt_f64(obj_f64("trigger", j, "step")?),
                        obj_u64("trigger", j, "every")?,
                        obj_u64("trigger", j, "until")?,
                        obj_u64("trigger", j, "steps_per_epoch")?,
                    ),
                    other => {
                        return Err(ConfigError::value(
                            "trigger",
                            j.to_string(),
                            format!("unknown trigger kind {other:?}"),
                        ))
                    }
                };
                spec.parse()
            }
            other => Err(ConfigError::value(
                "trigger",
                other.to_string(),
                "expected a spec string or object",
            )),
        }
    }
}

// ---------------------------------------------------------------------
// FamilySpec
// ---------------------------------------------------------------------

/// The parsed payload of a [`FamilySpec`]: which trigger family the
/// event-triggered engine runs.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Family {
    /// Plain SPARQ-SGD (Algorithm 1): the trigger tests the raw drift
    /// ‖x^{t+½} − x̂‖².
    Sparq,
    /// SQuARM-SGD (same authors, arXiv 1910.14280's companion): the
    /// trigger tests a momentum-buffered drift u ← β·u + (x^{t+½} − x̂);
    /// β = 0 degenerates bit-for-bit to [`Family::Sparq`].
    Squarm { beta: f64 },
}

impl Family {
    pub fn name(&self) -> &'static str {
        match self {
            Family::Sparq => "sparq",
            Family::Squarm { .. } => "squarm",
        }
    }
}

/// Typed algorithm-family spec (`sparq`, `squarm:BETA` with
/// β ∈ [0, 1)). The family composes with the `algo` field: it selects
/// the *trigger-side* behavior of the event-triggered engine, so it is
/// only meaningful for `algo = sparq` (enforced cross-field by
/// `ExperimentConfig::resolve`).
#[derive(Clone, Debug, PartialEq)]
pub struct FamilySpec {
    raw: String,
    family: Family,
}

spec_string_json!(FamilySpec);
spec_common!(FamilySpec, "bad family spec");

impl FamilySpec {
    pub fn family(&self) -> Family {
        self.family
    }

    /// The plain-SPARQ default (what an absent `family` key means).
    pub fn sparq() -> Self {
        "sparq".parse().expect("static spec")
    }

    pub fn squarm(beta: f64) -> Self {
        format!("squarm:{}", fmt_f64(beta)).as_str().into()
    }

    pub fn is_default(&self) -> bool {
        self.family == Family::Sparq
    }

    fn parse_spec(s: &str) -> Result<Self, ConfigError> {
        const FIELD: &str = "family";
        let family = match s.split_once(':') {
            None if s == "sparq" => Family::Sparq,
            Some(("squarm", beta)) => {
                let beta: f64 = beta.parse().map_err(|_| {
                    ConfigError::value(FIELD, s, format!("momentum beta {beta:?} is not a number"))
                })?;
                if !beta.is_finite() || !(0.0..1.0).contains(&beta) {
                    return Err(ConfigError::value(
                        FIELD,
                        s,
                        format!("momentum beta must lie in [0, 1), got {beta}"),
                    ));
                }
                Family::Squarm { beta }
            }
            _ => {
                return Err(ConfigError::value(FIELD, s, "unknown algorithm family")
                    .suggest("sparq or squarm:BETA (beta in [0, 1))"))
            }
        };
        Ok(FamilySpec {
            raw: s.to_string(),
            family,
        })
    }

    pub fn from_json(j: &Json) -> Result<Self, ConfigError> {
        match j {
            Json::Str(s) => s.parse(),
            Json::Obj(_) => {
                check_obj_keys("family", j, &["kind", "beta"])?;
                let spec = match obj_kind("family", j)?.as_str() {
                    "sparq" => "sparq".to_string(),
                    "squarm" => format!("squarm:{}", fmt_f64(obj_f64("family", j, "beta")?)),
                    other => {
                        return Err(ConfigError::value(
                            "family",
                            j.to_string(),
                            format!("unknown family kind {other:?}"),
                        ))
                    }
                };
                spec.parse()
            }
            other => Err(ConfigError::value(
                "family",
                other.to_string(),
                "expected a spec string or object",
            )),
        }
    }
}

// ---------------------------------------------------------------------
// LrSpec
// ---------------------------------------------------------------------

/// Typed learning-rate schedule spec (`const:E`, `invtime:A:B`,
/// `warmup:BASE:WEP:FACTOR:SPE:M1,M2,..`); payload is the validated
/// [`LrSchedule`].
#[derive(Clone, Debug, PartialEq)]
pub struct LrSpec {
    raw: String,
    sched: LrSchedule,
}

spec_string_json!(LrSpec);
spec_common!(LrSpec, "bad lr spec");

impl LrSpec {
    pub fn schedule(&self) -> &LrSchedule {
        &self.sched
    }

    pub fn constant(eta: f64) -> Self {
        format!("const:{}", fmt_f64(eta)).as_str().into()
    }

    pub fn inv_time(a: f64, b: f64) -> Self {
        format!("invtime:{}:{}", fmt_f64(a), fmt_f64(b)).as_str().into()
    }

    pub fn warmup(
        base: f64,
        warmup_epochs: usize,
        decay_factor: f64,
        steps_per_epoch: usize,
        milestones: &[usize],
    ) -> Self {
        let ms: Vec<String> = milestones.iter().map(|m| m.to_string()).collect();
        format!(
            "warmup:{}:{warmup_epochs}:{}:{steps_per_epoch}:{}",
            fmt_f64(base),
            fmt_f64(decay_factor),
            ms.join(",")
        )
        .as_str()
        .into()
    }

    fn parse_spec(s: &str) -> Result<Self, ConfigError> {
        let sched =
            LrSchedule::parse_checked(s).map_err(|reason| ConfigError::value("lr", s, reason))?;
        Ok(LrSpec {
            raw: s.to_string(),
            sched,
        })
    }

    pub fn from_json(j: &Json) -> Result<Self, ConfigError> {
        match j {
            Json::Str(s) => s.parse(),
            Json::Obj(_) => {
                check_obj_keys(
                    "lr",
                    j,
                    &[
                        "kind",
                        "eta",
                        "a",
                        "b",
                        "base",
                        "warmup_epochs",
                        "decay_factor",
                        "steps_per_epoch",
                        "milestones",
                    ],
                )?;
                let spec = match obj_kind("lr", j)?.as_str() {
                    "const" => format!("const:{}", fmt_f64(obj_f64("lr", j, "eta")?)),
                    "invtime" => format!(
                        "invtime:{}:{}",
                        fmt_f64(obj_f64("lr", j, "a")?),
                        fmt_f64(obj_f64("lr", j, "b")?)
                    ),
                    "warmup" => {
                        let ms = j
                            .get("milestones")
                            .and_then(Json::as_arr)
                            .ok_or_else(|| {
                                ConfigError::value(
                                    "lr",
                                    j.to_string(),
                                    "warmup needs a \"milestones\" array",
                                )
                            })?
                            .iter()
                            .map(|v| {
                                v.as_f64().map(fmt_f64).ok_or_else(|| {
                                    ConfigError::value(
                                        "lr",
                                        j.to_string(),
                                        "milestones must be numbers",
                                    )
                                })
                            })
                            .collect::<Result<Vec<_>, _>>()?;
                        format!(
                            "warmup:{}:{}:{}:{}:{}",
                            fmt_f64(obj_f64("lr", j, "base")?),
                            obj_u64("lr", j, "warmup_epochs")?,
                            fmt_f64(obj_f64("lr", j, "decay_factor")?),
                            obj_u64("lr", j, "steps_per_epoch")?,
                            ms.join(",")
                        )
                    }
                    other => {
                        return Err(ConfigError::value(
                            "lr",
                            j.to_string(),
                            format!("unknown lr kind {other:?}"),
                        ))
                    }
                };
                spec.parse()
            }
            other => Err(ConfigError::value(
                "lr",
                other.to_string(),
                "expected a spec string or object",
            )),
        }
    }
}

// ---------------------------------------------------------------------
// SyncSpec
// ---------------------------------------------------------------------

/// Typed synchronization-schedule spec. Legacy configs write the period
/// as the bare number `"h": 5`; the typed form also admits `every:H`,
/// `explicit:I1,I2,...` strings and `{"kind": "explicit", "indices":
/// [...]}` objects, making arbitrary index sets I_T (Section 2)
/// expressible from config for the first time. `to_json` emits a JSON
/// number for `every:H` so legacy hashes stay bit-identical.
#[derive(Clone, Debug, PartialEq)]
pub struct SyncSpec {
    raw: String,
    sched: SyncSchedule,
}

spec_common!(SyncSpec, "bad sync spec");

impl SyncSpec {
    pub fn schedule(&self) -> &SyncSchedule {
        &self.sched
    }

    /// `every:H` (H = 0 is tolerated for legacy configs and behaves as
    /// H = 1, exactly as the old `u64` field did).
    pub fn every(h: u64) -> Self {
        SyncSpec {
            raw: format!("every:{h}"),
            sched: SyncSchedule::EveryH(h),
        }
    }

    pub fn explicit(indices: &[u64]) -> Self {
        let parts: Vec<String> = indices.iter().map(|i| i.to_string()).collect();
        format!("explicit:{}", parts.join(",")).as_str().into()
    }

    /// The period H for `every:H` specs (`None` for explicit index
    /// sets).
    pub fn period(&self) -> Option<u64> {
        match &self.sched {
            SyncSchedule::EveryH(h) => Some(*h),
            SyncSchedule::Explicit(_) => None,
        }
    }

    fn parse_spec(s: &str) -> Result<Self, ConfigError> {
        // Legacy form: the bare period.
        if let Ok(h) = s.parse::<u64>() {
            return Ok(SyncSpec::every(h));
        }
        let sched =
            SyncSchedule::parse(s).map_err(|reason| ConfigError::value("h", s, reason))?;
        Ok(SyncSpec {
            raw: s.to_string(),
            sched,
        })
    }

    /// Accepts a number (legacy `"h": 5`), a spec string, or a
    /// `{"kind": ...}` object.
    pub fn from_json(j: &Json) -> Result<Self, ConfigError> {
        match j {
            Json::Num(x) => {
                if !x.is_finite() || *x < 0.0 || x.fract() != 0.0 {
                    return Err(ConfigError::value(
                        "h",
                        fmt_f64(*x),
                        "must be a non-negative integer",
                    ));
                }
                Ok(SyncSpec::every(*x as u64))
            }
            Json::Str(s) => s.parse(),
            Json::Obj(_) => {
                check_obj_keys("h", j, &["kind", "h", "indices"])?;
                match obj_kind("h", j)?.as_str() {
                    "every" => Ok(SyncSpec::every(obj_u64("h", j, "h")?)),
                    "explicit" => {
                        let idx = j
                            .get("indices")
                            .and_then(Json::as_arr)
                            .ok_or_else(|| {
                                ConfigError::value(
                                    "h",
                                    j.to_string(),
                                    "explicit needs an \"indices\" array",
                                )
                            })?
                            .iter()
                            .map(|v| {
                                // Reject-don't-default: fractional or
                                // negative indices must not be silently
                                // cast into different sync rounds.
                                let x = v.as_f64().ok_or_else(|| {
                                    ConfigError::value(
                                        "h",
                                        j.to_string(),
                                        "indices must be numbers",
                                    )
                                })?;
                                if !x.is_finite() || x < 0.0 || x.fract() != 0.0 {
                                    return Err(ConfigError::value(
                                        "h",
                                        j.to_string(),
                                        format!(
                                            "indices must be non-negative integers, got {x}"
                                        ),
                                    ));
                                }
                                Ok(x as u64)
                            })
                            .collect::<Result<Vec<u64>, _>>()?;
                        let parts: Vec<String> = idx.iter().map(|i| i.to_string()).collect();
                        format!("explicit:{}", parts.join(",")).parse()
                    }
                    other => Err(ConfigError::value(
                        "h",
                        j.to_string(),
                        format!("unknown sync kind {other:?}"),
                    )),
                }
            }
            other => Err(ConfigError::value(
                "h",
                other.to_string(),
                "expected a number, spec string, or object",
            )),
        }
    }

    /// JSON form: a number for `every:H` (bit-compatible with the legacy
    /// `"h"` field), the spec string otherwise.
    pub fn to_json(&self) -> Json {
        match &self.sched {
            SyncSchedule::EveryH(h) => Json::Num(*h as f64),
            SyncSchedule::Explicit(_) => Json::Str(self.raw.clone()),
        }
    }
}

impl From<u64> for SyncSpec {
    fn from(h: u64) -> SyncSpec {
        SyncSpec::every(h)
    }
}

// ---------------------------------------------------------------------
// TopologySpec
// ---------------------------------------------------------------------

/// Typed topology spec (`ring`, `complete`, `star`, `path`, `torus`,
/// `hypercube`, `regularD`); payload is the [`TopologyKind`].
/// Node-count compatibility (torus squares, hypercube powers of two,
/// regular-degree parity) is a cross-field property checked by
/// `ExperimentConfig::resolve`.
#[derive(Clone, Debug, PartialEq)]
pub struct TopologySpec {
    raw: String,
    kind: TopologyKind,
}

spec_string_json!(TopologySpec);
spec_common!(TopologySpec, "unknown topology");

impl TopologySpec {
    pub fn kind(&self) -> TopologyKind {
        self.kind
    }

    pub fn of_kind(kind: TopologyKind) -> Self {
        TopologySpec {
            raw: kind.spec_str(),
            kind,
        }
    }

    pub fn ring() -> Self {
        Self::of_kind(TopologyKind::Ring)
    }

    pub fn torus() -> Self {
        Self::of_kind(TopologyKind::Torus)
    }

    fn parse_spec(s: &str) -> Result<Self, ConfigError> {
        let kind = TopologyKind::parse(s).ok_or_else(|| {
            ConfigError::value("topology", s, "unknown topology kind")
                .suggest("ring, complete, star, path, torus, hypercube, or regularD")
        })?;
        Ok(TopologySpec {
            raw: s.to_string(),
            kind,
        })
    }

    pub fn from_json(j: &Json) -> Result<Self, ConfigError> {
        match j {
            Json::Str(s) => s.parse(),
            Json::Obj(_) => {
                check_obj_keys("topology", j, &["kind", "degree"])?;
                let kind = obj_kind("topology", j)?;
                let spec = if kind == "regular" {
                    format!("regular{}", obj_u64("topology", j, "degree")?)
                } else {
                    kind
                };
                spec.parse()
            }
            other => Err(ConfigError::value(
                "topology",
                other.to_string(),
                "expected a spec string or object",
            )),
        }
    }
}

// ---------------------------------------------------------------------
// ScheduleSpec (time-varying topology)
// ---------------------------------------------------------------------

/// The parsed payload of a [`ScheduleSpec`].
#[derive(Clone, Debug, PartialEq)]
pub enum ScheduleKindSpec {
    Static,
    Switch {
        kinds: Vec<TopologyKind>,
        period: u64,
    },
    Sample {
        base: TopologyKind,
        m: usize,
    },
}

/// Typed time-varying-topology spec (`static`, `switch:K1,K2,...:P`,
/// `sample:BASE:M`). This is the single grammar for the schedule —
/// `graph::dynamic::TopologySchedule::parse` goes through it. The
/// n-dependent constraint (`M` vs the base graph's edge count) is
/// checked when the schedule is built against a node count
/// (`resolve()` / `TopologySchedule::parse`).
#[derive(Clone, Debug, PartialEq)]
pub struct ScheduleSpec {
    raw: String,
    kind: ScheduleKindSpec,
}

spec_string_json!(ScheduleSpec);
spec_common!(ScheduleSpec, "bad topology_schedule spec");

impl ScheduleSpec {
    pub fn kind(&self) -> &ScheduleKindSpec {
        &self.kind
    }

    /// The fixed-topology default (also what the legacy empty string
    /// means).
    pub fn fixed() -> Self {
        "static".parse().expect("static spec")
    }

    pub fn switch(kinds: &[TopologyKind], period: u64) -> Self {
        let names: Vec<String> = kinds.iter().map(|k| k.spec_str()).collect();
        format!("switch:{}:{period}", names.join(",")).as_str().into()
    }

    pub fn sample(base: TopologyKind, m: usize) -> Self {
        format!("sample:{}:{m}", base.spec_str()).as_str().into()
    }

    pub fn is_static(&self) -> bool {
        matches!(self.kind, ScheduleKindSpec::Static)
    }

    /// Build the replayable schedule for an n-node run (the n-dependent
    /// edge-count check happens here).
    pub fn build(
        &self,
        n: usize,
        seed: u64,
    ) -> Result<crate::graph::TopologySchedule, ConfigError> {
        crate::graph::TopologySchedule::from_spec(self, n, seed)
            .map_err(|reason| ConfigError::value("topology_schedule", self.as_str(), reason))
    }

    fn parse_spec(s: &str) -> Result<Self, ConfigError> {
        const FIELD: &str = "topology_schedule";
        let usage = "static, switch:K1,K2,...:P, or sample:BASE:M";
        if s.is_empty() || s == "static" {
            return Ok(ScheduleSpec {
                raw: s.to_string(),
                kind: ScheduleKindSpec::Static,
            });
        }
        let parts: Vec<&str> = s.split(':').collect();
        let topo = |k: &str| -> Result<TopologyKind, ConfigError> {
            TopologyKind::parse(k)
                .ok_or_else(|| ConfigError::value(FIELD, s, format!("unknown topology {k:?}")))
        };
        let kind = match parts.as_slice() {
            ["switch", kinds, period] => {
                let kinds: Vec<TopologyKind> =
                    kinds.split(',').map(topo).collect::<Result<_, _>>()?;
                if kinds.is_empty() {
                    return Err(ConfigError::value(FIELD, s, "switch needs at least one topology"));
                }
                let period: u64 = period.parse().map_err(|_| {
                    let what = format!("switch period {period:?} is not an integer");
                    ConfigError::value(FIELD, s, what)
                })?;
                if period == 0 {
                    return Err(ConfigError::value(FIELD, s, "switch period must be >= 1"));
                }
                ScheduleKindSpec::Switch { kinds, period }
            }
            ["sample", base, m] => {
                let base = topo(base)?;
                let m: usize = m.parse().map_err(|_| {
                    let what = format!("sample edge count {m:?} is not an integer");
                    ConfigError::value(FIELD, s, what)
                })?;
                if m == 0 {
                    let what = "sample needs at least one edge per round";
                    return Err(ConfigError::value(FIELD, s, what));
                }
                ScheduleKindSpec::Sample { base, m }
            }
            _ => return Err(ConfigError::value(FIELD, s, "unknown schedule").suggest(usage)),
        };
        Ok(ScheduleSpec {
            raw: s.to_string(),
            kind,
        })
    }

    pub fn from_json(j: &Json) -> Result<Self, ConfigError> {
        match j {
            Json::Str(s) => s.parse(),
            Json::Obj(_) => {
                check_obj_keys("topology_schedule", j, &["kind", "kinds", "period", "base", "m"])?;
                let spec = match obj_kind("topology_schedule", j)?.as_str() {
                    "static" => "static".to_string(),
                    "switch" => {
                        let kinds = j
                            .get("kinds")
                            .and_then(Json::as_arr)
                            .ok_or_else(|| {
                                ConfigError::value(
                                    "topology_schedule",
                                    j.to_string(),
                                    "switch needs a \"kinds\" array",
                                )
                            })?
                            .iter()
                            .map(|v| {
                                v.as_str().map(str::to_string).ok_or_else(|| {
                                    ConfigError::value(
                                        "topology_schedule",
                                        j.to_string(),
                                        "kinds must be strings",
                                    )
                                })
                            })
                            .collect::<Result<Vec<_>, _>>()?;
                        format!(
                            "switch:{}:{}",
                            kinds.join(","),
                            obj_u64("topology_schedule", j, "period")?
                        )
                    }
                    "sample" => {
                        let base = j.get("base").and_then(Json::as_str).ok_or_else(|| {
                            ConfigError::value(
                                "topology_schedule",
                                j.to_string(),
                                "sample needs a string \"base\"",
                            )
                        })?;
                        format!("sample:{base}:{}", obj_u64("topology_schedule", j, "m")?)
                    }
                    other => {
                        return Err(ConfigError::value(
                            "topology_schedule",
                            j.to_string(),
                            format!("unknown schedule kind {other:?}"),
                        ))
                    }
                };
                spec.parse()
            }
            other => Err(ConfigError::value(
                "topology_schedule",
                other.to_string(),
                "expected a spec string or object",
            )),
        }
    }
}

// ---------------------------------------------------------------------
// LinkSpec
// ---------------------------------------------------------------------

/// Typed link-fault spec (`none`, `drop:P`, `straggler:I:P`, segments
/// joined with `+`). Straggler indices are range-checked against the
/// node count by `ExperimentConfig::resolve`; the seeded
/// [`LinkModel`](crate::comm::LinkModel) is built per run via
/// [`LinkSpec::build`].
#[derive(Clone, Debug, PartialEq)]
pub struct LinkSpec {
    raw: String,
    drop_p: f64,
    stragglers: Vec<(usize, f64)>,
}

spec_string_json!(LinkSpec);
spec_common!(LinkSpec, "bad link spec");

impl LinkSpec {
    /// The loss-free default.
    pub fn ideal() -> Self {
        "none".parse().expect("static spec")
    }

    /// Per-copy drop probability p ∈ [0, 1).
    pub fn drop(p: f64) -> Self {
        format!("drop:{}", fmt_f64(p)).as_str().into()
    }

    /// Add a straggler segment (node i skips sync rounds w.p. p).
    pub fn with_straggler(self, node: usize, p: f64) -> Self {
        let seg = format!("straggler:{node}:{}", fmt_f64(p));
        if self.is_ideal() {
            seg.as_str().into()
        } else {
            format!("{}+{seg}", self.raw).as_str().into()
        }
    }

    pub fn is_ideal(&self) -> bool {
        self.drop_p == 0.0 && self.stragglers.is_empty()
    }

    pub fn drop_p(&self) -> f64 {
        self.drop_p
    }

    pub fn stragglers(&self) -> &[(usize, f64)] {
        &self.stragglers
    }

    /// Instantiate the seeded fault process for one run.
    pub fn build(&self, seed: u64) -> crate::comm::LinkModel {
        crate::comm::LinkModel::parse(&self.raw, seed).expect("validated at parse time")
    }

    fn parse_spec(s: &str) -> Result<Self, ConfigError> {
        // LinkModel::parse owns the grammar; the seed is irrelevant for
        // validation.
        let model = crate::comm::LinkModel::parse(s, 0)
            .map_err(|reason| ConfigError::value("link", s, reason))?;
        Ok(LinkSpec {
            raw: s.to_string(),
            drop_p: model.drop_p,
            stragglers: model.stragglers,
        })
    }

    pub fn from_json(j: &Json) -> Result<Self, ConfigError> {
        match j {
            Json::Str(s) => s.parse(),
            Json::Obj(_) => {
                check_obj_keys("link", j, &["drop", "stragglers"])?;
                let mut segs = Vec::new();
                if let Some(p) = j.get("drop") {
                    let p = p.as_f64().ok_or_else(|| {
                        ConfigError::value("link", j.to_string(), "\"drop\" must be a number")
                    })?;
                    segs.push(format!("drop:{}", fmt_f64(p)));
                }
                if let Some(list) = j.get("stragglers") {
                    let arr = list.as_arr().ok_or_else(|| {
                        ConfigError::value(
                            "link",
                            j.to_string(),
                            "\"stragglers\" must be an array of {node, p} objects",
                        )
                    })?;
                    for item in arr {
                        let node = obj_u64("link", item, "node")?;
                        let p = obj_f64("link", item, "p")?;
                        segs.push(format!("straggler:{node}:{}", fmt_f64(p)));
                    }
                }
                if segs.is_empty() {
                    return "none".parse();
                }
                segs.join("+").parse()
            }
            other => Err(ConfigError::value(
                "link",
                other.to_string(),
                "expected a spec string or object",
            )),
        }
    }
}

// ---------------------------------------------------------------------
// FaultSpec
// ---------------------------------------------------------------------

/// Typed fault-plan spec (`none`, or `+`-joined `crash:I:T0:T1`,
/// `partition:T0:T1:A|B`, `corrupt:P` segments — see
/// [`FaultPlan`](crate::comm::FaultPlan) for the grammar). Node indices
/// are range-checked against the node count by
/// `ExperimentConfig::resolve`; the seeded plan is built per run via
/// [`FaultSpec::build`].
#[derive(Clone, Debug, PartialEq)]
pub struct FaultSpec {
    raw: String,
    plan: crate::comm::FaultPlan,
}

spec_string_json!(FaultSpec);
spec_common!(FaultSpec, "bad fault spec");

impl FaultSpec {
    /// The fault-free default.
    pub fn none() -> Self {
        "none".parse().expect("static spec")
    }

    pub fn is_none(&self) -> bool {
        self.plan.is_ideal()
    }

    /// The parsed (unseeded) plan — schedule queries only.
    pub fn plan(&self) -> &crate::comm::FaultPlan {
        &self.plan
    }

    /// Instantiate the seeded fault plan for one run.
    pub fn build(&self, seed: u64) -> crate::comm::FaultPlan {
        crate::comm::FaultPlan::parse(&self.raw, seed).expect("validated at parse time")
    }

    fn parse_spec(s: &str) -> Result<Self, ConfigError> {
        // FaultPlan::parse owns the grammar; the seed is irrelevant for
        // validation.
        let plan = crate::comm::FaultPlan::parse(s, 0)
            .map_err(|reason| ConfigError::value("fault", s, reason))?;
        Ok(FaultSpec {
            raw: s.to_string(),
            plan,
        })
    }

    pub fn from_json(j: &Json) -> Result<Self, ConfigError> {
        match j {
            Json::Str(s) => s.parse(),
            Json::Obj(_) => {
                check_obj_keys("fault", j, &["crash", "partition", "corrupt"])?;
                let mut segs = Vec::new();
                if let Some(list) = j.get("crash") {
                    let arr = list.as_arr().ok_or_else(|| {
                        ConfigError::value(
                            "fault",
                            j.to_string(),
                            "\"crash\" must be an array of {node, down, up} objects",
                        )
                    })?;
                    for item in arr {
                        let node = obj_u64("fault", item, "node")?;
                        let down = obj_u64("fault", item, "down")?;
                        let up = obj_u64("fault", item, "up")?;
                        segs.push(format!("crash:{node}:{down}:{up}"));
                    }
                }
                if let Some(list) = j.get("partition") {
                    let arr = list.as_arr().ok_or_else(|| {
                        ConfigError::value(
                            "fault",
                            j.to_string(),
                            "\"partition\" must be an array of {from, to, groups} objects",
                        )
                    })?;
                    for item in arr {
                        let from = obj_u64("fault", item, "from")?;
                        let to = obj_u64("fault", item, "to")?;
                        let groups = item.get("groups").and_then(Json::as_arr).ok_or_else(
                            || {
                                ConfigError::value(
                                    "fault",
                                    item.to_string(),
                                    "partition needs \"groups\": an array of index arrays",
                                )
                            },
                        )?;
                        let mut rendered = Vec::new();
                        for g in groups {
                            let members = g.as_arr().ok_or_else(|| {
                                ConfigError::value(
                                    "fault",
                                    g.to_string(),
                                    "each partition group must be an array of node indices",
                                )
                            })?;
                            let ids: Result<Vec<String>, ConfigError> = members
                                .iter()
                                .map(|m| {
                                    m.as_f64()
                                        .filter(|x| x.is_finite() && *x >= 0.0 && x.fract() == 0.0)
                                        .map(|x| format!("{}", x as u64))
                                        .ok_or_else(|| {
                                            ConfigError::value(
                                                "fault",
                                                m.to_string(),
                                                "partition member is not a node index",
                                            )
                                        })
                                })
                                .collect();
                            rendered.push(ids?.join(","));
                        }
                        segs.push(format!("partition:{from}:{to}:{}", rendered.join("|")));
                    }
                }
                if let Some(p) = j.get("corrupt") {
                    let p = p.as_f64().ok_or_else(|| {
                        ConfigError::value("fault", j.to_string(), "\"corrupt\" must be a number")
                    })?;
                    segs.push(format!("corrupt:{}", fmt_f64(p)));
                }
                if segs.is_empty() {
                    return "none".parse();
                }
                segs.join("+").parse()
            }
            other => Err(ConfigError::value(
                "fault",
                other.to_string(),
                "expected a spec string or object",
            )),
        }
    }
}

// ---------------------------------------------------------------------
// ProblemSpec
// ---------------------------------------------------------------------

/// The parsed payload of a [`ProblemSpec`].
#[derive(Clone, Debug, PartialEq)]
pub enum ProblemKind {
    /// `quadratic:D[:NOISE[:SPREAD]]` — gradient-noise σ (default 0.05)
    /// and heterogeneity spread (default 1.0).
    Quadratic { d: usize, noise: f32, spread: f32 },
    /// `logreg:DIN:CLASSES:BATCH` (heterogeneous by-class shards).
    LogReg {
        din: usize,
        classes: usize,
        batch: usize,
    },
    /// `mlp:DIN:HIDDEN:CLASSES:BATCH` (IID shards).
    Mlp {
        din: usize,
        hidden: usize,
        classes: usize,
        batch: usize,
    },
}

impl ProblemKind {
    /// The flat parameter dimension the problem will train (used by
    /// `resolve()` for k-vs-d sanity without building the dataset).
    pub fn dim(&self) -> usize {
        match self {
            ProblemKind::Quadratic { d, .. } => *d,
            ProblemKind::LogReg { din, classes, .. } => {
                crate::problems::LogRegProblem::flat_dim(*din, *classes)
            }
            ProblemKind::Mlp {
                din,
                hidden,
                classes,
                ..
            } => crate::problems::MlpProblem::flat_dim(*din, *hidden, *classes),
        }
    }
}

/// Typed problem spec; payload is the [`ProblemKind`]. The dataset /
/// gradient source is built per run by `experiments::builder`.
#[derive(Clone, Debug, PartialEq)]
pub struct ProblemSpec {
    raw: String,
    kind: ProblemKind,
}

spec_string_json!(ProblemSpec);
spec_common!(ProblemSpec, "unknown problem spec");

impl ProblemSpec {
    pub fn kind(&self) -> &ProblemKind {
        &self.kind
    }

    pub fn dim(&self) -> usize {
        self.kind.dim()
    }

    pub fn quadratic(d: usize) -> Self {
        format!("quadratic:{d}").as_str().into()
    }

    pub fn quadratic_noisy(d: usize, noise: f32, spread: f32) -> Self {
        format!("quadratic:{d}:{noise}:{spread}").as_str().into()
    }

    pub fn logreg(din: usize, classes: usize, batch: usize) -> Self {
        format!("logreg:{din}:{classes}:{batch}").as_str().into()
    }

    pub fn mlp(din: usize, hidden: usize, classes: usize, batch: usize) -> Self {
        format!("mlp:{din}:{hidden}:{classes}:{batch}").as_str().into()
    }

    fn parse_spec(s: &str) -> Result<Self, ConfigError> {
        const FIELD: &str = "problem";
        let usage = "quadratic:D[:NOISE[:SPREAD]], logreg:DIN:CLASSES:BATCH, \
                     or mlp:DIN:HIDDEN:CLASSES:BATCH";
        let dim = |what: &str, v: &str| -> Result<usize, ConfigError> {
            let x: usize = v.parse().map_err(|_| {
                ConfigError::value(FIELD, s, format!("{what} {v:?} is not a positive integer"))
            })?;
            if x == 0 {
                return Err(ConfigError::value(FIELD, s, format!("{what} must be >= 1")));
            }
            Ok(x)
        };
        let f32_nonneg = |what: &str, v: &str| -> Result<f32, ConfigError> {
            let x: f32 = v.parse().map_err(|_| {
                ConfigError::value(FIELD, s, format!("{what} {v:?} is not a number"))
            })?;
            if !x.is_finite() || x < 0.0 {
                return Err(ConfigError::value(
                    FIELD,
                    s,
                    format!("{what} must be finite and non-negative, got {x}"),
                ));
            }
            Ok(x)
        };
        let parts: Vec<&str> = s.split(':').collect();
        let kind = match parts.as_slice() {
            ["quadratic", rest @ ..] if (1..=3).contains(&rest.len()) => ProblemKind::Quadratic {
                d: dim("dimension", rest[0])?,
                noise: rest.get(1).map(|v| f32_nonneg("noise", v)).transpose()?.unwrap_or(0.05),
                spread: rest.get(2).map(|v| f32_nonneg("spread", v)).transpose()?.unwrap_or(1.0),
            },
            ["logreg", din, classes, batch] => ProblemKind::LogReg {
                din: dim("input dimension", din)?,
                classes: {
                    let c = dim("class count", classes)?;
                    if c < 2 {
                        return Err(ConfigError::value(FIELD, s, "classes must be >= 2"));
                    }
                    c
                },
                batch: dim("batch size", batch)?,
            },
            ["mlp", din, hidden, classes, batch] => ProblemKind::Mlp {
                din: dim("input dimension", din)?,
                hidden: dim("hidden width", hidden)?,
                classes: {
                    let c = dim("class count", classes)?;
                    if c < 2 {
                        return Err(ConfigError::value(FIELD, s, "classes must be >= 2"));
                    }
                    c
                },
                batch: dim("batch size", batch)?,
            },
            _ => return Err(ConfigError::value(FIELD, s, "unknown problem").suggest(usage)),
        };
        Ok(ProblemSpec {
            raw: s.to_string(),
            kind,
        })
    }

    pub fn from_json(j: &Json) -> Result<Self, ConfigError> {
        match j {
            Json::Str(s) => s.parse(),
            Json::Obj(_) => {
                check_obj_keys(
                    "problem",
                    j,
                    &["kind", "d", "noise", "spread", "din", "hidden", "classes", "batch"],
                )?;
                let spec = match obj_kind("problem", j)?.as_str() {
                    "quadratic" => {
                        let d = obj_u64("problem", j, "d")?;
                        match (j.get("noise"), j.get("spread")) {
                            (None, None) => format!("quadratic:{d}"),
                            (noise, spread) => format!(
                                "quadratic:{d}:{}:{}",
                                fmt_f64(noise.and_then(Json::as_f64).unwrap_or(0.05)),
                                fmt_f64(spread.and_then(Json::as_f64).unwrap_or(1.0)),
                            ),
                        }
                    }
                    "logreg" => format!(
                        "logreg:{}:{}:{}",
                        obj_u64("problem", j, "din")?,
                        obj_u64("problem", j, "classes")?,
                        obj_u64("problem", j, "batch")?
                    ),
                    "mlp" => format!(
                        "mlp:{}:{}:{}:{}",
                        obj_u64("problem", j, "din")?,
                        obj_u64("problem", j, "hidden")?,
                        obj_u64("problem", j, "classes")?,
                        obj_u64("problem", j, "batch")?
                    ),
                    other => {
                        return Err(ConfigError::value(
                            "problem",
                            j.to_string(),
                            format!("unknown problem kind {other:?}"),
                        ))
                    }
                };
                spec.parse()
            }
            other => Err(ConfigError::value(
                "problem",
                other.to_string(),
                "expected a spec string or object",
            )),
        }
    }
}

// ---------------------------------------------------------------------
// ClusterSpec
// ---------------------------------------------------------------------

/// Which socket family a `sparq cluster` deployment exchanges frames
/// over.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SocketKind {
    /// Unix domain sockets under the cluster directory (the default;
    /// single-host deployments, no ports to allocate).
    Uds,
    /// Loopback/LAN TCP; each node binds an OS-assigned port and
    /// advertises it through the cluster directory.
    Tcp,
}

impl SocketKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            SocketKind::Uds => "uds",
            SocketKind::Tcp => "tcp",
        }
    }
}

/// Typed cluster-deployment spec: `uds`, `tcp`, or `tcp@HOST`, each
/// optionally followed by `:LEASE[:HEARTBEAT[:CONNECT]]` (seconds).
///
/// Deployment knobs only — socket family, membership-lease timings,
/// dial patience. None of them can change what the run computes (the
/// cluster runtime is pinned bit-identical to the in-process engine),
/// so `config_hash` normalizes the field away: the same experiment
/// hashes identically whether it runs in-process or as N processes.
/// Omitted from the JSON form when default, so pre-cluster configs keep
/// their exact serialized bytes.
#[derive(Clone, Debug, PartialEq)]
pub struct ClusterSpec {
    raw: String,
    kind: SocketKind,
    host: String,
    lease_secs: f64,
    heartbeat_secs: f64,
    connect_timeout_secs: f64,
}

spec_string_json!(ClusterSpec);
spec_common!(ClusterSpec, "bad cluster spec");

impl Default for ClusterSpec {
    fn default() -> Self {
        ClusterSpec::uds()
    }
}

impl ClusterSpec {
    /// The default deployment: Unix domain sockets, lease 5 s,
    /// heartbeat 1 s, connect patience 30 s.
    pub fn uds() -> Self {
        "uds".parse().expect("static spec")
    }

    pub fn kind(&self) -> SocketKind {
        self.kind
    }

    /// TCP bind/advertise host (ignored for UDS).
    pub fn host(&self) -> &str {
        &self.host
    }

    /// Membership-lease duration: a node claim older than this is dead.
    pub fn lease_secs(&self) -> f64 {
        self.lease_secs
    }

    /// Claim-heartbeat cadence (must stay well under the lease).
    pub fn heartbeat_secs(&self) -> f64 {
        self.heartbeat_secs
    }

    /// How long dial/accept waits for a peer before giving up (covers
    /// respawn + checkpoint replay of a killed node).
    pub fn connect_timeout_secs(&self) -> f64 {
        self.connect_timeout_secs
    }

    pub fn is_default(&self) -> bool {
        *self == ClusterSpec::default()
    }

    fn parse_spec(s: &str) -> Result<Self, ConfigError> {
        const FIELD: &str = "cluster";
        let usage = "uds, tcp, or tcp@HOST, optionally :LEASE[:HEARTBEAT[:CONNECT]] seconds";
        let mut parts = s.split(':');
        let head = parts.next().unwrap_or("");
        let (kind, host) = if head == "uds" {
            (SocketKind::Uds, String::new())
        } else if head == "tcp" {
            (SocketKind::Tcp, "127.0.0.1".to_string())
        } else if let Some(host) = head.strip_prefix("tcp@") {
            if host.is_empty() {
                return Err(ConfigError::value(FIELD, s, "tcp@ needs a host").suggest(usage));
            }
            (SocketKind::Tcp, host.to_string())
        } else {
            return Err(ConfigError::value(FIELD, s, "unknown socket kind").suggest(usage));
        };
        let secs = |what: &str, v: &str| -> Result<f64, ConfigError> {
            let x: f64 = v.parse().map_err(|_| {
                ConfigError::value(FIELD, s, format!("{what} {v:?} is not a number"))
            })?;
            if !x.is_finite() || x <= 0.0 {
                return Err(ConfigError::value(
                    FIELD,
                    s,
                    format!("{what} must be a positive number of seconds, got {x}"),
                ));
            }
            Ok(x)
        };
        let lease_secs = parts.next().map(|v| secs("lease", v)).transpose()?.unwrap_or(5.0);
        let heartbeat_secs = parts
            .next()
            .map(|v| secs("heartbeat", v))
            .transpose()?
            .unwrap_or(1.0);
        let connect_timeout_secs = parts
            .next()
            .map(|v| secs("connect timeout", v))
            .transpose()?
            .unwrap_or(30.0);
        if parts.next().is_some() {
            return Err(ConfigError::value(FIELD, s, "too many segments").suggest(usage));
        }
        if heartbeat_secs >= lease_secs {
            return Err(ConfigError::value(
                FIELD,
                s,
                format!(
                    "heartbeat ({heartbeat_secs}s) must be shorter than the lease ({lease_secs}s)"
                ),
            ));
        }
        Ok(ClusterSpec {
            raw: s.to_string(),
            kind,
            host,
            lease_secs,
            heartbeat_secs,
            connect_timeout_secs,
        })
    }

    pub fn from_json(j: &Json) -> Result<Self, ConfigError> {
        match j {
            Json::Str(s) => s.parse(),
            Json::Obj(_) => {
                check_obj_keys(
                    "cluster",
                    j,
                    &["kind", "host", "lease", "heartbeat", "connect"],
                )?;
                let kind = obj_kind("cluster", j)?;
                let mut spec = match (kind.as_str(), j.get("host").and_then(Json::as_str)) {
                    ("uds", None) => "uds".to_string(),
                    ("uds", Some(_)) => {
                        return Err(ConfigError::value(
                            "cluster",
                            j.to_string(),
                            "uds takes no host",
                        ))
                    }
                    ("tcp", None) => "tcp".to_string(),
                    ("tcp", Some(host)) => format!("tcp@{host}"),
                    (other, _) => {
                        return Err(ConfigError::value(
                            "cluster",
                            j.to_string(),
                            format!("unknown socket kind {other:?}"),
                        ))
                    }
                };
                let timing: Vec<Option<f64>> = ["lease", "heartbeat", "connect"]
                    .iter()
                    .map(|k| j.get(k).and_then(Json::as_f64))
                    .collect();
                if timing.iter().any(Option::is_some) {
                    // Positional segments: later knobs force earlier
                    // ones to their defaults when unspecified.
                    let defaults = [5.0, 1.0, 30.0];
                    let last = timing.iter().rposition(Option::is_some).expect("any some");
                    for (slot, dflt) in timing.iter().zip(defaults).take(last + 1) {
                        spec.push_str(&format!(":{}", fmt_f64(slot.unwrap_or(dflt))));
                    }
                }
                spec.parse()
            }
            other => Err(ConfigError::value(
                "cluster",
                other.to_string(),
                "expected a spec string or object",
            )),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn raw_strings_survive_roundtrips_verbatim() {
        // parse → Display is the identity on every accepted legacy form,
        // including float spellings ("2.0" vs "2") — the property that
        // keeps config_hash bit-compatible.
        for s in [
            "sign_topk:10%",
            "sign_topk:10",
            "sign_topk:10%:paper",
            "topk:100",
            "qsgd_topk:5:4",
            "const:5000",
            "piecewise:2.0:1.0:10:60:100",
            "poly:2:0.5",
            "invtime:100:1",
            "warmup:0.05:5:5:100:150,250",
            "drop:0.1+straggler:0:0.5",
            "switch:ring,torus:500",
            "sample:torus:6",
            "quadratic:64:0.1:0.5",
            "logreg:784:10:5",
            "mlp:3072:128:10:32",
        ] {
            match s.split(':').next().unwrap() {
                "sign_topk" | "topk" | "qsgd_topk" => {
                    assert_eq!(CompressorSpec::from_str(s).unwrap().to_string(), s)
                }
                "const" | "piecewise" | "poly" => {
                    assert_eq!(TriggerSpec::from_str(s).unwrap().to_string(), s)
                }
                "invtime" | "warmup" => assert_eq!(LrSpec::from_str(s).unwrap().to_string(), s),
                "drop" => assert_eq!(LinkSpec::from_str(s).unwrap().to_string(), s),
                "switch" | "sample" => {
                    assert_eq!(ScheduleSpec::from_str(s).unwrap().to_string(), s)
                }
                "quadratic" | "logreg" | "mlp" => {
                    assert_eq!(ProblemSpec::from_str(s).unwrap().to_string(), s)
                }
                other => panic!("unrouted spec family {other}"),
            }
        }
    }

    #[test]
    fn compressor_parses_and_builds() {
        let c = CompressorSpec::from_str("sign_topk:10%").unwrap();
        assert!(matches!(
            c.kind(),
            CompressorKind::SignTopK { k: KSpec::Percent(p), paper: false } if *p == 10.0
        ));
        assert_eq!(c.resolved_k(200), Some(20));
        assert_eq!(c.build(200).name(), "sign_topk(k=20)");
        assert_eq!(CompressorSpec::top_k(10).as_str(), "topk:10");
        assert_eq!(
            CompressorSpec::sign_top_k_pct(10.0).paper_accounting().as_str(),
            "sign_topk:10%:paper"
        );
        assert!(CompressorSpec::from_str("topk:0").is_err());
        assert!(CompressorSpec::from_str("topk:-5%").is_err());
        assert!(CompressorSpec::from_str("topk:200%").is_err());
        assert!(CompressorSpec::from_str("qsgd:0").is_err());
        assert!(CompressorSpec::from_str("nope").is_err());
    }

    #[test]
    fn structured_object_forms_parse_to_canonical_strings() {
        let c = CompressorSpec::from_json(&Json::parse(r#"{"kind":"topk","k":100}"#).unwrap())
            .unwrap();
        assert_eq!(c.as_str(), "topk:100");
        let c = CompressorSpec::from_json(
            &Json::parse(r#"{"kind":"sign_topk","k":"10%","paper":true}"#).unwrap(),
        )
        .unwrap();
        assert_eq!(c.as_str(), "sign_topk:10%:paper");
        let t =
            TriggerSpec::from_json(&Json::parse(r#"{"kind":"const","c0":5000}"#).unwrap()).unwrap();
        assert_eq!(t.as_str(), "const:5000");
        let l = LrSpec::from_json(&Json::parse(r#"{"kind":"invtime","a":100,"b":1}"#).unwrap())
            .unwrap();
        assert_eq!(l.as_str(), "invtime:100:1");
        let hj = Json::parse(r#"{"kind":"explicit","indices":[3,5,10]}"#).unwrap();
        let h = SyncSpec::from_json(&hj).unwrap();
        assert_eq!(h.as_str(), "explicit:3,5,10");
        let s = ScheduleSpec::from_json(
            &Json::parse(r#"{"kind":"switch","kinds":["ring","torus"],"period":500}"#).unwrap(),
        )
        .unwrap();
        assert_eq!(s.as_str(), "switch:ring,torus:500");
        let k = LinkSpec::from_json(
            &Json::parse(r#"{"drop":0.1,"stragglers":[{"node":0,"p":0.5}]}"#).unwrap(),
        )
        .unwrap();
        assert_eq!(k.as_str(), "drop:0.1+straggler:0:0.5");
        let p = ProblemSpec::from_json(
            &Json::parse(r#"{"kind":"logreg","din":784,"classes":10,"batch":5}"#).unwrap(),
        )
        .unwrap();
        assert_eq!(p.as_str(), "logreg:784:10:5");
        // typo'd object keys are rejected, not ignored
        assert!(CompressorSpec::from_json(
            &Json::parse(r#"{"kind":"topk","K":100}"#).unwrap()
        )
        .is_err());
    }

    #[test]
    fn sync_spec_accepts_numbers_strings_and_objects() {
        assert_eq!(SyncSpec::from_json(&Json::Num(5.0)).unwrap().period(), Some(5));
        assert_eq!(SyncSpec::from_str("5").unwrap().period(), Some(5));
        assert_eq!(SyncSpec::from_str("every:5").unwrap().period(), Some(5));
        let e = SyncSpec::from_str("explicit:3,5,10").unwrap();
        assert_eq!(e.period(), None);
        assert!(e.schedule().is_sync(2));
        // every:H serializes back to the legacy number
        assert_eq!(SyncSpec::every(5).to_json(), Json::Num(5.0));
        assert_eq!(e.to_json(), Json::Str("explicit:3,5,10".into()));
        assert!(SyncSpec::from_str("explicit:5,3").is_err());
        assert!(SyncSpec::from_json(&Json::Num(2.5)).is_err());
        // fractional/negative explicit indices are rejected, not cast
        for bad in [
            r#"{"kind":"explicit","indices":[2.5,10]}"#,
            r#"{"kind":"explicit","indices":[-1,5]}"#,
        ] {
            let j = Json::parse(bad).unwrap();
            assert!(SyncSpec::from_json(&j).is_err(), "{bad}");
        }
    }

    #[test]
    fn problem_dim_matches_builders() {
        assert_eq!(ProblemSpec::from_str("quadratic:64").unwrap().dim(), 64);
        assert_eq!(ProblemSpec::from_str("logreg:784:10:5").unwrap().dim(), 7850);
        assert_eq!(
            ProblemSpec::from_str("mlp:3072:128:10:32").unwrap().dim(),
            394634
        );
        assert!(ProblemSpec::from_str("quadratic:0").is_err());
        assert!(ProblemSpec::from_str("logreg:10:1:5").is_err());
        assert!(ProblemSpec::from_str("svm:1").is_err());
    }

    #[test]
    fn link_spec_builds_the_same_model_as_direct_parse() {
        let spec = LinkSpec::from_str("drop:0.3+straggler:1:0.5").unwrap();
        assert_eq!(spec.drop_p(), 0.3);
        assert_eq!(spec.stragglers(), &[(1, 0.5)]);
        let built = spec.build(7);
        let direct = crate::comm::LinkModel::parse("drop:0.3+straggler:1:0.5", 7).unwrap();
        assert_eq!(built, direct);
        assert!(LinkSpec::from_str("drop:1.5").is_err());
        assert!(LinkSpec::ideal().is_ideal());
        assert_eq!(
            LinkSpec::drop(0.1).with_straggler(0, 0.5).as_str(),
            "drop:0.1+straggler:0:0.5"
        );
    }

    #[test]
    fn fault_spec_builds_the_same_plan_as_direct_parse() {
        let raw = "crash:3:200:400+partition:500:700:0-7|8-15+corrupt:0.02";
        let spec = FaultSpec::from_str(raw).unwrap();
        assert_eq!(spec.as_str(), raw); // raw preserved, ranges unexpanded
        assert!(!spec.is_none());
        let built = spec.build(7);
        let direct = crate::comm::FaultPlan::parse(raw, 7).unwrap();
        assert_eq!(built, direct);
        assert!(FaultSpec::none().is_none());
        assert!(FaultSpec::from_str("crash:0:10:5").is_err());
        assert!(FaultSpec::from_str("corrupt:2").is_err());
        // structured JSON object form canonicalizes to segments
        let j = Json::parse(
            r#"{"crash":[{"node":3,"down":200,"up":400}],
                "partition":[{"from":500,"to":700,"groups":[[0,1],[2,3]]}],
                "corrupt":0.02}"#,
        )
        .unwrap();
        let spec = FaultSpec::from_json(&j).unwrap();
        assert_eq!(
            spec.as_str(),
            "crash:3:200:400+partition:500:700:0,1|2,3+corrupt:0.02"
        );
        // typo'd keys rejected
        assert!(FaultSpec::from_json(&Json::parse(r#"{"crsh":[]}"#).unwrap()).is_err());
    }

    #[test]
    fn trigger_spec_percoord_form() {
        let t = TriggerSpec::from_str("percoord:4").unwrap();
        assert!(t.per_coord());
        assert_eq!(t.schedule(), &ThresholdSchedule::Constant(4.0));
        assert_eq!(t.as_str(), "percoord:4"); // raw preserved
        let trig = t.event_trigger();
        assert!(trig.per_coord);
        assert_eq!(trig.coord_threshold(3, 0.5), Some(4.0 * 0.25));
        // norm-mode specs keep per_coord off and coord_threshold None
        let n = TriggerSpec::from_str("const:4").unwrap();
        assert!(!n.per_coord());
        assert_eq!(n.event_trigger().coord_threshold(3, 0.5), None);
        // typed constructor and JSON object form agree on the canonical string
        assert_eq!(TriggerSpec::percoord(4.0).as_str(), "percoord:4");
        let j = Json::parse(r#"{"kind":"percoord","c0":4}"#).unwrap();
        assert_eq!(TriggerSpec::from_json(&j).unwrap().as_str(), "percoord:4");
        assert!(TriggerSpec::from_str("percoord:-1").is_err());
        assert!(TriggerSpec::from_str("percoord:inf").is_err());
    }

    #[test]
    fn family_spec_grammar_and_bounds() {
        let f = FamilySpec::from_str("sparq").unwrap();
        assert_eq!(f.family(), Family::Sparq);
        assert!(f.is_default());
        let f = FamilySpec::from_str("squarm:0.9").unwrap();
        assert_eq!(f.family(), Family::Squarm { beta: 0.9 });
        assert!(!f.is_default());
        assert_eq!(f.as_str(), "squarm:0.9");
        // β = 0 is valid (the SPARQ-degenerate pin) but NOT the default
        // spec — it still routes through the SQuARM composition.
        let zero = FamilySpec::squarm(0.0);
        assert_eq!(zero.family(), Family::Squarm { beta: 0.0 });
        assert!(!zero.is_default());
        assert_eq!(zero.as_str(), "squarm:0");
        // bounds: β ∈ [0, 1)
        assert!(FamilySpec::from_str("squarm:1").is_err());
        assert!(FamilySpec::from_str("squarm:-0.1").is_err());
        assert!(FamilySpec::from_str("squarm:nan").is_err());
        assert!(FamilySpec::from_str("squarm:lots").is_err());
        let err = FamilySpec::from_str("motef").unwrap_err();
        assert!(err.to_string().contains("family"), "{err}");
        // JSON object form
        let j = Json::parse(r#"{"kind":"squarm","beta":0.5}"#).unwrap();
        assert_eq!(FamilySpec::from_json(&j).unwrap().as_str(), "squarm:0.5");
        let j = Json::parse(r#"{"kind":"sparq"}"#).unwrap();
        assert!(FamilySpec::from_json(&j).unwrap().is_default());
        assert!(FamilySpec::from_json(&Json::parse(r#"{"kind":"squarm"}"#).unwrap()).is_err());
    }

    #[test]
    fn string_equality_with_specs_still_works() {
        let c = CompressorSpec::from_str("sign_topk:10").unwrap();
        assert!(c == "sign_topk:10");
        assert!(c != "sign_topk:10%");
        let t: TriggerSpec = "const:100".into();
        assert!(t == "const:100");
    }

    #[test]
    #[should_panic(expected = "bad trigger spec")]
    fn from_str_panics_preserve_legacy_messages() {
        let _: TriggerSpec = "poly:2:1.5".into();
    }

    #[test]
    fn cluster_specs_parse_and_roundtrip() {
        let dflt = ClusterSpec::default();
        assert_eq!(dflt.as_str(), "uds");
        assert!(dflt.is_default());
        assert_eq!(dflt.kind(), SocketKind::Uds);
        assert_eq!(dflt.lease_secs(), 5.0);
        assert_eq!(dflt.heartbeat_secs(), 1.0);
        assert_eq!(dflt.connect_timeout_secs(), 30.0);

        let c = ClusterSpec::from_str("tcp@10.0.0.5:8:2:60").unwrap();
        assert_eq!(c.kind(), SocketKind::Tcp);
        assert_eq!(c.host(), "10.0.0.5");
        assert_eq!(c.lease_secs(), 8.0);
        assert_eq!(c.heartbeat_secs(), 2.0);
        assert_eq!(c.connect_timeout_secs(), 60.0);
        assert!(!c.is_default());
        assert_eq!(c.to_json(), Json::Str("tcp@10.0.0.5:8:2:60".into()));

        let c = ClusterSpec::from_str("tcp").unwrap();
        assert_eq!(c.host(), "127.0.0.1");
        let c = ClusterSpec::from_str("uds:10").unwrap();
        assert_eq!(c.lease_secs(), 10.0);
        assert_eq!(c.heartbeat_secs(), 1.0);

        // rejections: bad kind, bare host, non-positive timings,
        // heartbeat >= lease, trailing garbage
        assert!(ClusterSpec::from_str("udp").is_err());
        assert!(ClusterSpec::from_str("tcp@").is_err());
        assert!(ClusterSpec::from_str("uds:0").is_err());
        assert!(ClusterSpec::from_str("uds:5:-1").is_err());
        assert!(ClusterSpec::from_str("uds:5:5").is_err());
        assert!(ClusterSpec::from_str("uds:5:1:30:9").is_err());
        let err = ClusterSpec::from_str("what").unwrap_err();
        assert_eq!(err.field(), Some("cluster"), "{err}");

        // JSON object form
        let j = Json::parse(r#"{"kind":"tcp","host":"h","lease":6}"#).unwrap();
        assert_eq!(ClusterSpec::from_json(&j).unwrap().as_str(), "tcp@h:6");
        let j = Json::parse(r#"{"kind":"uds","heartbeat":2}"#).unwrap();
        let c = ClusterSpec::from_json(&j).unwrap();
        assert_eq!(c.as_str(), "uds:5:2");
        assert_eq!(c.lease_secs(), 5.0);
        let j = Json::parse(r#"{"kind":"uds","host":"nope"}"#).unwrap();
        assert!(ClusterSpec::from_json(&j).is_err());
    }
}
