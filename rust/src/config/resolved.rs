//! Cross-field resolution: [`ExperimentConfig::resolve`] turns a typed
//! config into a [`ResolvedConfig`] — the proof that the *composition*
//! of knobs is coherent, not just each knob alone.
//!
//! Field-local validity is established at parse time by the spec types;
//! what remains are the constraints that span fields: the topology (and
//! every graph a schedule names) must be constructible on `nodes`,
//! straggler indices must be in range, a `sample:BASE:M` schedule must
//! not ask for more edges than the base graph has, a k-sparse compressor
//! must not name more coordinates than the problem has parameters, and
//! the momentum/γ scalars must be semantically meaningful. Everything
//! downstream — `experiments::builder`, the [`Run`](crate::run::Run)
//! handle, the sweep engine — consumes the resolved form, so a config
//! that resolves is a config that runs.
//!
//! ```
//! use sparq::config::{CompressorSpec, ExperimentConfig};
//!
//! let cfg = ExperimentConfig {
//!     nodes: 4,
//!     compressor: CompressorSpec::top_k(8),
//!     ..Default::default()
//! };
//! let resolved = cfg.resolve().expect("coherent composition");
//! assert_eq!(resolved.dim, 64); // quadratic:64, the default problem
//!
//! // Compositions that cannot run fail at resolve time, not mid-run:
//! let bad = ExperimentConfig {
//!     nodes: 4,
//!     link: "straggler:9:0.5".into(), // node 9 of 4
//!     ..Default::default()
//! };
//! assert!(bad.resolve().is_err());
//! ```

use super::error::ConfigError;
use super::specs::Family;
use super::{Algo, ExperimentConfig};
use crate::comm::{FaultPlan, LinkModel};
use crate::graph::TopologySchedule;
use crate::schedule::{LrSchedule, SyncSchedule};
use crate::trigger::ThresholdSchedule;

/// How the consensus step size γ is chosen (decoded from the config's
/// signed-`f64` convention: > 0 pins, 0 tunes, < 0 pins zero exactly).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum GammaMode {
    /// Tune from the mixing matrix's spectrum
    /// (`SpectralInfo::gamma_tuned`).
    Tuned,
    /// Use exactly this value (γ = 0 disables mixing — the ablation
    /// diagnostic).
    Pinned(f64),
}

impl GammaMode {
    /// The pinned value, if any.
    pub fn pinned(&self) -> Option<f64> {
        match self {
            GammaMode::Tuned => None,
            GammaMode::Pinned(g) => Some(*g),
        }
    }
}

/// A cross-field-validated config plus the derived objects every
/// consumer needs (see module docs). Constructed only by
/// [`ExperimentConfig::resolve`].
#[derive(Clone, Debug)]
pub struct ResolvedConfig {
    cfg: ExperimentConfig,
    /// Flat parameter dimension of the problem (known without building
    /// the dataset).
    pub dim: usize,
    /// Synchronization index set I_T.
    pub sync: SyncSchedule,
    /// Event-trigger threshold schedule c_t.
    pub trigger: ThresholdSchedule,
    /// EventGraD-style per-coordinate trigger mode (`percoord:C` specs):
    /// each coordinate fires independently instead of the norm test.
    pub trigger_per_coord: bool,
    /// Algorithm family for the event-triggered engine (trigger-side
    /// composition: plain SPARQ or momentum-buffered SQuARM).
    pub family: Family,
    /// Learning-rate schedule η_t.
    pub lr: LrSchedule,
    /// Seeded link-fault process (seed already mixed in).
    pub link: LinkModel,
    /// Seeded node/partition/corruption fault plan (seed already mixed
    /// in); `FaultPlan::ideal()` when the config declares no faults.
    pub fault: FaultPlan,
    /// Replayable time-varying topology schedule.
    pub schedule: TopologySchedule,
    /// Consensus step-size policy.
    pub gamma: GammaMode,
}

impl ResolvedConfig {
    /// The validated source config.
    pub fn config(&self) -> &ExperimentConfig {
        &self.cfg
    }
}

impl ExperimentConfig {
    /// Check every cross-field constraint and derive the objects a run
    /// needs. The single validation gate of the experiment surface: a
    /// config that resolves builds and runs without config-related
    /// panics.
    pub fn resolve(&self) -> Result<ResolvedConfig, ConfigError> {
        if self.nodes == 0 {
            return Err(ConfigError::value(
                "nodes",
                "0",
                "need at least one node",
            ));
        }

        // The graph(s) in force must be constructible on `nodes`.
        let schedule = self.topology_schedule.build(self.nodes, self.seed)?;
        if schedule.is_static() {
            self.topology
                .kind()
                .check_nodes(self.nodes)
                .map_err(|reason| {
                    ConfigError::value("topology", self.topology.as_str(), reason)
                })?;
        } else if self.topology != ExperimentConfig::default().topology {
            // A non-static schedule dictates the starting matrix (switch
            // phase 0 / the sampling base graph) and the `topology` field
            // is NOT consulted — the schedule spec names its own graphs.
            // Reject the contradictory combination instead of silently
            // ignoring an explicit topology.
            return Err(ConfigError::conflict(
                "topology",
                "topology_schedule",
                format!(
                    "the schedule {:?} names its own graphs, so the topology {:?} \
                     would be ignored",
                    self.topology_schedule.as_str(),
                    self.topology.as_str()
                ),
            )
            .suggest("remove one of the two; the schedule wins"));
        }

        // Straggler indices must name real nodes.
        let link = self.link.build(self.seed);
        for &(node, _) in self.link.stragglers() {
            if node >= self.nodes {
                return Err(ConfigError::value(
                    "link",
                    self.link.as_str(),
                    format!(
                        "straggler node {node} out of range for {} nodes",
                        self.nodes
                    ),
                ));
            }
        }

        // Fault-plan indices must name real nodes, and a plan with
        // outages must activate within the configured horizon — a crash
        // scheduled after the last step is almost certainly a typo.
        let fault = self.fault.build(self.seed);
        self.fault.plan().check_nodes(self.nodes).map_err(|reason| {
            ConfigError::value("fault", self.fault.as_str(), reason)
        })?;
        if self.steps > 0 && fault.has_outages() {
            if let Some(first) = fault.first_activation() {
                if first >= self.steps {
                    return Err(ConfigError::value(
                        "fault",
                        self.fault.as_str(),
                        format!(
                            "first fault window opens at t = {first}, but the \
                             run ends at t = {}",
                            self.steps
                        ),
                    )
                    .suggest("move the window before `steps`, or raise `steps`"));
                }
            }
        }

        // A k-sparse compressor cannot name more coordinates than the
        // problem has parameters (percent forms resolve within range by
        // construction).
        let dim = self.problem.dim();
        if let Some(k) = self.compressor.resolved_k(dim) {
            if k > dim {
                return Err(ConfigError::value(
                    "compressor",
                    self.compressor.as_str(),
                    format!("k = {k} exceeds the problem dimension d = {dim}"),
                )
                .suggest(format!("k <= {dim}, or a percentage form like \"topk:10%\"")));
            }
        }

        // The family knob composes with `algo` — it selects trigger-side
        // behavior of the *event-triggered* engine, so it is meaningless
        // for CHOCO/vanilla (which have no trigger). Reject the
        // contradiction instead of silently running plain CHOCO.
        if !self.family.is_default() && self.algo != Algo::Sparq {
            return Err(ConfigError::conflict(
                "family",
                "algo",
                format!(
                    "family {:?} requires the event-triggered engine (algo = \"sparq\"), \
                     got algo = {:?}",
                    self.family.as_str(),
                    self.algo.as_str()
                ),
            )
            .suggest("set algo to \"sparq\", or drop the family field"));
        }
        // SQuARM's trigger is the whole-vector norm of the buffered drift;
        // a per-coordinate trigger would leave β silently unused (the
        // coordinate mask bypasses the momentum path in the engine).
        if !self.family.is_default() && self.trigger.per_coord() {
            return Err(ConfigError::conflict(
                "family",
                "trigger",
                format!(
                    "family {:?} evaluates a whole-vector momentum-buffered trigger, \
                     which cannot compose with the per-coordinate trigger {:?}",
                    self.family.as_str(),
                    self.trigger.as_str()
                ),
            )
            .suggest("use a norm trigger (e.g. \"const:C\"), or drop the family field"));
        }

        if !self.momentum.is_finite() || !(0.0..1.0).contains(&self.momentum) {
            return Err(ConfigError::value(
                "momentum",
                format!("{}", self.momentum),
                "must lie in [0, 1)",
            ));
        }
        if !self.gamma.is_finite() {
            return Err(ConfigError::value(
                "gamma",
                format!("{}", self.gamma),
                "must be finite (> 0 pins, 0 tunes, < 0 pins zero)",
            ));
        }
        let gamma = if self.gamma > 0.0 {
            GammaMode::Pinned(self.gamma)
        } else if self.gamma < 0.0 {
            GammaMode::Pinned(0.0)
        } else {
            GammaMode::Tuned
        };

        Ok(ResolvedConfig {
            cfg: self.clone(),
            dim,
            sync: self.h.schedule().clone(),
            trigger: self.trigger.schedule().clone(),
            trigger_per_coord: self.trigger.per_coord(),
            family: self.family.family(),
            lr: self.lr.schedule().clone(),
            link,
            fault,
            schedule,
            gamma,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::specs::TopologySpec;

    #[test]
    fn default_config_resolves() {
        let r = ExperimentConfig::default().resolve().unwrap();
        assert_eq!(r.dim, 64);
        assert_eq!(r.gamma, GammaMode::Tuned);
        assert!(r.link.is_ideal());
        assert!(r.schedule.is_static());
    }

    #[test]
    fn gamma_sign_convention_decodes() {
        let with_gamma = |gamma: f64| ExperimentConfig {
            gamma,
            ..Default::default()
        };
        assert_eq!(with_gamma(0.25).resolve().unwrap().gamma, GammaMode::Pinned(0.25));
        assert_eq!(with_gamma(-1.0).resolve().unwrap().gamma, GammaMode::Pinned(0.0));
        assert_eq!(with_gamma(0.0).resolve().unwrap().gamma, GammaMode::Tuned);
        assert!(with_gamma(f64::NAN).resolve().is_err());
    }

    #[test]
    fn straggler_out_of_range_is_a_resolve_error() {
        let cfg = ExperimentConfig {
            nodes: 4,
            link: "straggler:4:0.5".into(),
            ..Default::default()
        };
        let err = cfg.resolve().unwrap_err().to_string();
        assert!(err.contains("out of range"), "{err}");
        // in-range resolves
        let ok = ExperimentConfig {
            nodes: 4,
            link: "straggler:3:0.5".into(),
            ..Default::default()
        };
        assert!(ok.resolve().is_ok());
    }

    #[test]
    fn topology_node_compatibility_is_a_resolve_error() {
        let cfg = ExperimentConfig {
            nodes: 15,
            topology: TopologySpec::torus(),
            ..Default::default()
        };
        let err = cfg.resolve().unwrap_err().to_string();
        assert!(err.contains("perfect-square"), "{err}");
        // and inside schedules too
        let cfg = ExperimentConfig {
            nodes: 15,
            topology_schedule: "switch:ring,torus:100".into(),
            ..Default::default()
        };
        assert!(cfg.resolve().is_err());
        let cfg = ExperimentConfig {
            nodes: 16,
            topology_schedule: "switch:ring,torus:100".into(),
            ..Default::default()
        };
        assert!(cfg.resolve().is_ok());
    }

    #[test]
    fn conflicting_topology_and_schedule_is_a_resolve_error() {
        let cfg = ExperimentConfig {
            nodes: 16,
            topology: TopologySpec::torus(),
            topology_schedule: "switch:ring,torus:100".into(),
            ..Default::default()
        };
        let err = cfg.resolve().unwrap_err().to_string();
        assert!(err.contains("names its own graphs"), "{err}");
    }

    #[test]
    fn oversized_k_is_a_resolve_error() {
        let cfg = ExperimentConfig {
            compressor: "topk:100".into(),
            problem: "quadratic:64".into(),
            ..Default::default()
        };
        let err = cfg.resolve().unwrap_err().to_string();
        assert!(err.contains("exceeds"), "{err}");
        // percent forms always resolve in range
        let cfg = ExperimentConfig {
            compressor: "topk:100%".into(),
            problem: "quadratic:64".into(),
            ..Default::default()
        };
        assert!(cfg.resolve().is_ok());
    }

    #[test]
    fn momentum_range_is_a_resolve_error() {
        let with_momentum = |momentum: f64| ExperimentConfig {
            momentum,
            ..Default::default()
        };
        assert!(with_momentum(-0.5).resolve().is_err());
        assert!(with_momentum(1.0).resolve().is_err());
        assert!(with_momentum(0.9).resolve().is_ok());
    }

    #[test]
    fn fault_plan_node_range_is_a_resolve_error() {
        let cfg = ExperimentConfig {
            nodes: 4,
            fault: "crash:4:100:200".into(),
            ..Default::default()
        };
        let err = cfg.resolve().unwrap_err().to_string();
        assert!(err.contains("fault"), "{err}");
        assert!(err.contains("4 nodes"), "{err}");
        // partitions are checked too
        let cfg = ExperimentConfig {
            nodes: 4,
            fault: "partition:100:200:0,1|2,9".into(),
            ..Default::default()
        };
        assert!(cfg.resolve().is_err());
        // in-range resolves and carries the seeded plan
        let cfg = ExperimentConfig {
            nodes: 4,
            fault: "crash:3:100:200".into(),
            ..Default::default()
        };
        let r = cfg.resolve().unwrap();
        assert!(r.fault.is_down(3, 150));
        assert!(!r.fault.is_down(3, 250));
    }

    #[test]
    fn fault_plan_past_horizon_is_a_resolve_error() {
        let cfg = ExperimentConfig {
            steps: 500,
            fault: "crash:0:600:700".into(),
            ..Default::default()
        };
        let err = cfg.resolve().unwrap_err().to_string();
        assert!(err.contains("run ends"), "{err}");
        // corruption alone has no window, so it is horizon-exempt
        let cfg = ExperimentConfig {
            steps: 500,
            fault: "corrupt:0.05".into(),
            ..Default::default()
        };
        assert!(cfg.resolve().is_ok());
        // steps = 0 (caller-driven horizon) skips the check
        let cfg = ExperimentConfig {
            steps: 0,
            fault: "crash:0:600:700".into(),
            ..Default::default()
        };
        assert!(cfg.resolve().is_ok());
    }

    #[test]
    fn family_requires_the_event_triggered_engine() {
        use crate::config::Algo;
        // squarm composes with algo = sparq only
        let cfg = ExperimentConfig {
            family: "squarm:0.9".into(),
            ..Default::default()
        };
        let r = cfg.resolve().unwrap();
        assert_eq!(r.family, Family::Squarm { beta: 0.9 });
        for algo in [Algo::Choco, Algo::Vanilla] {
            let cfg = ExperimentConfig {
                algo: algo.clone(),
                family: "squarm:0.9".into(),
                ..Default::default()
            };
            let err = cfg.resolve().unwrap_err().to_string();
            assert!(err.contains("family"), "{err}");
            assert!(err.contains("sparq"), "{err}");
        }
        // the default family composes with every algo
        for algo in [Algo::Sparq, Algo::Choco, Algo::Vanilla] {
            let cfg = ExperimentConfig {
                algo,
                ..Default::default()
            };
            assert_eq!(cfg.resolve().unwrap().family, Family::Sparq);
        }
        // squarm's whole-vector momentum trigger cannot compose with a
        // per-coordinate trigger (β would be silently unused)
        let cfg = ExperimentConfig {
            family: "squarm:0.9".into(),
            trigger: "percoord:4".into(),
            ..Default::default()
        };
        let err = cfg.resolve().unwrap_err().to_string();
        assert!(err.contains("per-coordinate"), "{err}");
        // but the per-coordinate trigger composes with the default family
        let cfg = ExperimentConfig {
            trigger: "percoord:4".into(),
            ..Default::default()
        };
        assert!(cfg.resolve().is_ok());
    }

    #[test]
    fn percoord_trigger_flows_through_resolve() {
        let cfg = ExperimentConfig {
            trigger: "percoord:4".into(),
            ..Default::default()
        };
        let r = cfg.resolve().unwrap();
        assert!(r.trigger_per_coord);
        assert_eq!(r.trigger, crate::trigger::ThresholdSchedule::Constant(4.0));
        let r = ExperimentConfig::default().resolve().unwrap();
        assert!(!r.trigger_per_coord);
    }

    #[test]
    fn sample_edge_budget_is_a_resolve_error() {
        let cfg = ExperimentConfig {
            nodes: 8,
            topology_schedule: "sample:ring:9".into(),
            ..Default::default()
        };
        assert!(cfg.resolve().is_err());
        let cfg = ExperimentConfig {
            nodes: 8,
            topology_schedule: "sample:ring:8".into(),
            ..Default::default()
        };
        assert!(cfg.resolve().is_ok());
    }
}
