//! The single structured error type of the config surface.
//!
//! Every way an [`ExperimentConfig`](super::ExperimentConfig) can be
//! wrong — an unparsable field spec, an out-of-range value, a pair of
//! fields that contradict each other, a typo'd JSON key — surfaces as
//! one [`ConfigError`] carrying the offending **field**, the rejected
//! **value**, a human-readable **reason**, and (when there is an obvious
//! fix) a **suggestion**. This replaces the pre-redesign mix of
//! `Option`-returning and `Result<_, String>`-returning module parsers:
//! callers match on structure, render with `Display`, or bubble through
//! `?` — nothing needs to grep message strings to find out *which* knob
//! was wrong.

use std::fmt;

/// Structured configuration error (see module docs). The `Display` form
/// is what the CLI prints and what the snapshot tests in
/// `rust/tests/config_golden.rs` pin.
#[derive(Clone, Debug, PartialEq)]
pub enum ConfigError {
    /// A field's value failed to parse or validate.
    Value {
        /// Config field (or sub-field path like `trigger.eps`).
        field: String,
        /// The rejected input, verbatim.
        value: String,
        reason: String,
        /// An actionable fix or the expected grammar, when one exists.
        suggestion: Option<String>,
    },
    /// Two fields are individually valid but contradict each other
    /// (found by [`ExperimentConfig::resolve`](super::ExperimentConfig::resolve)).
    Conflict {
        field: String,
        other: String,
        reason: String,
        suggestion: Option<String>,
    },
    /// An unknown key in a JSON config object (typo safety: a misspelled
    /// knob must not silently fall back to its default).
    UnknownKey { key: String, valid: Vec<String> },
    /// The input is not shaped like a config at all (non-object JSON,
    /// unreadable file, ...).
    Shape { reason: String },
}

impl ConfigError {
    /// A field-value rejection.
    pub fn value(
        field: impl Into<String>,
        value: impl Into<String>,
        reason: impl Into<String>,
    ) -> ConfigError {
        ConfigError::Value {
            field: field.into(),
            value: value.into(),
            reason: reason.into(),
            suggestion: None,
        }
    }

    /// A cross-field contradiction.
    pub fn conflict(
        field: impl Into<String>,
        other: impl Into<String>,
        reason: impl Into<String>,
    ) -> ConfigError {
        ConfigError::Conflict {
            field: field.into(),
            other: other.into(),
            reason: reason.into(),
            suggestion: None,
        }
    }

    /// Attach an actionable suggestion (no-op for `UnknownKey`/`Shape`,
    /// which carry their own fix).
    pub fn suggest(mut self, s: impl Into<String>) -> ConfigError {
        match &mut self {
            ConfigError::Value { suggestion, .. } | ConfigError::Conflict { suggestion, .. } => {
                *suggestion = Some(s.into());
            }
            _ => {}
        }
        self
    }

    /// Replace the reported value (e.g. widen a sub-field rejection to
    /// the whole spec string the user wrote).
    pub fn with_value(mut self, v: impl Into<String>) -> ConfigError {
        if let ConfigError::Value { value, .. } = &mut self {
            *value = v.into();
        }
        self
    }

    /// The config field the error anchors to, when it has one.
    pub fn field(&self) -> Option<&str> {
        match self {
            ConfigError::Value { field, .. } | ConfigError::Conflict { field, .. } => Some(field),
            ConfigError::UnknownKey { key, .. } => Some(key),
            ConfigError::Shape { .. } => None,
        }
    }
}

impl fmt::Display for ConfigError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ConfigError::Value {
                field,
                value,
                reason,
                suggestion,
            } => {
                write!(f, "invalid {field} {value:?}: {reason}")?;
                if let Some(s) = suggestion {
                    write!(f, " (try: {s})")?;
                }
                Ok(())
            }
            ConfigError::Conflict {
                field,
                other,
                reason,
                suggestion,
            } => {
                write!(f, "config sets both {field} and {other}: {reason}")?;
                if let Some(s) = suggestion {
                    write!(f, " (try: {s})")?;
                }
                Ok(())
            }
            ConfigError::UnknownKey { key, valid } => {
                write!(f, "unknown config key {key:?}; valid keys: {}", valid.join(", "))
            }
            ConfigError::Shape { reason } => write!(f, "{reason}"),
        }
    }
}

impl std::error::Error for ConfigError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_includes_field_value_reason_suggestion() {
        let e = ConfigError::value("trigger", "poly:2:1.5", "eps must lie in (0, 1)")
            .suggest("poly:2:0.5");
        let s = e.to_string();
        assert!(s.contains("trigger"), "{s}");
        assert!(s.contains("poly:2:1.5"), "{s}");
        assert!(s.contains("(0, 1)"), "{s}");
        assert!(s.contains("try: poly:2:0.5"), "{s}");
        assert_eq!(e.field(), Some("trigger"));
    }

    #[test]
    fn unknown_key_lists_valid_keys() {
        let e = ConfigError::UnknownKey {
            key: "trigerr".into(),
            valid: vec!["trigger".into(), "lr".into()],
        };
        let s = e.to_string();
        assert!(s.contains("trigerr") && s.contains("trigger, lr"), "{s}");
    }

    #[test]
    fn conflict_names_both_fields() {
        let e = ConfigError::conflict("topology", "topology_schedule", "the schedule wins");
        let s = e.to_string();
        assert!(s.contains("topology") && s.contains("topology_schedule"), "{s}");
    }
}
