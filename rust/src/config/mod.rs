//! Typed experiment configuration (JSON in/out) + presets mirroring the
//! paper's Section 5 setups.

use crate::util::json::Json;

/// Which algorithm to instantiate.
#[derive(Clone, Debug, PartialEq)]
pub enum Algo {
    Sparq,
    Choco,
    Vanilla,
}

impl Algo {
    pub fn parse(s: &str) -> Option<Algo> {
        match s {
            "sparq" => Some(Algo::Sparq),
            "choco" => Some(Algo::Choco),
            "vanilla" => Some(Algo::Vanilla),
            _ => None,
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            Algo::Sparq => "sparq",
            Algo::Choco => "choco",
            Algo::Vanilla => "vanilla",
        }
    }
}

/// Full experiment description. String-spec fields use the module parsers
/// (`compress::parse`, `ThresholdSchedule::parse`, `LrSchedule::parse`,
/// `TopologyKind::parse`) so configs stay flat and diff-friendly.
#[derive(Clone, Debug, PartialEq)]
pub struct ExperimentConfig {
    pub name: String,
    pub algo: Algo,
    pub nodes: usize,
    pub topology: String,
    /// Time-varying topology spec (`graph::dynamic::TopologySchedule`):
    /// "static" (default — use `topology` unchanged),
    /// "switch:K1,K2,...:P", or "sample:BASE:M". Non-static specs name
    /// their own graphs and take precedence over `topology`, which is
    /// then ignored.
    pub topology_schedule: String,
    /// Link-fault spec (`comm::link::LinkModel`): "none" (default),
    /// "drop:P", "straggler:I:P", joined with '+'.
    pub link: String,
    pub compressor: String,
    pub trigger: String,
    pub lr: String,
    /// Sync period H.
    pub h: u64,
    pub steps: u64,
    pub eval_every: u64,
    pub momentum: f64,
    pub seed: u64,
    /// Problem spec: "quadratic:D[:NOISE[:SPREAD]]" (gradient-noise σ,
    /// heterogeneity spread; defaults 0.05 / 1.0),
    /// "logreg:DIN:CLASSES:BATCH", "mlp:DIN:HIDDEN:CLASSES:BATCH".
    pub problem: String,
    /// Consensus step size γ: > 0 pins the value, 0 ⇒ tuned heuristic
    /// (`SpectralInfo::gamma_tuned`), < 0 pins γ = 0 exactly (mixing
    /// disabled — the ablation diagnostic; plain 0 cannot mean that
    /// because it is the unset default).
    pub gamma: f64,
    /// Worker threads for the coordinator's per-node phases (1 ⇒
    /// sequential, 0 ⇒ available CPUs); bit-for-bit deterministic across
    /// values.
    pub workers: usize,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            name: "default".into(),
            algo: Algo::Sparq,
            nodes: 8,
            topology: "ring".into(),
            topology_schedule: "static".into(),
            link: "none".into(),
            compressor: "sign_topk:10%".into(),
            trigger: "const:100".into(),
            lr: "invtime:100:1".into(),
            h: 5,
            steps: 1000,
            eval_every: 50,
            momentum: 0.0,
            seed: 42,
            problem: "quadratic:64".into(),
            gamma: 0.0,
            workers: 1,
        }
    }
}

impl ExperimentConfig {
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("name", self.name.as_str())
            .set("algo", self.algo.as_str())
            .set("nodes", self.nodes)
            .set("topology", self.topology.as_str())
            .set("topology_schedule", self.topology_schedule.as_str())
            .set("link", self.link.as_str())
            .set("compressor", self.compressor.as_str())
            .set("trigger", self.trigger.as_str())
            .set("lr", self.lr.as_str())
            .set("h", self.h)
            .set("steps", self.steps)
            .set("eval_every", self.eval_every)
            .set("momentum", self.momentum)
            .set("seed", self.seed)
            .set("problem", self.problem.as_str())
            .set("gamma", self.gamma)
            .set("workers", self.workers)
    }

    /// Every key `from_json` understands (used for typo rejection).
    pub const KEYS: &[&str] = &[
        "name",
        "algo",
        "nodes",
        "topology",
        "topology_schedule",
        "link",
        "compressor",
        "trigger",
        "lr",
        "h",
        "steps",
        "eval_every",
        "momentum",
        "seed",
        "problem",
        "gamma",
        "workers",
    ];

    pub fn from_json(j: &Json) -> Result<ExperimentConfig, String> {
        let obj = j
            .as_obj()
            .ok_or_else(|| "config must be a JSON object".to_string())?;
        // Reject unknown keys: a typo ("trigerr") must not silently fall
        // back to the default schedule.
        for key in obj.keys() {
            if !Self::KEYS.contains(&key.as_str()) {
                return Err(format!(
                    "unknown config key {key:?}; valid keys: {}",
                    Self::KEYS.join(", ")
                ));
            }
        }
        let base = ExperimentConfig::default();
        let s = |k: &str, dflt: &str| -> Result<String, String> {
            match j.get(k) {
                None => Ok(dflt.to_string()),
                Some(v) => v
                    .as_str()
                    .map(str::to_string)
                    .ok_or_else(|| format!("config key {k:?} must be a string")),
            }
        };
        // Unsigned integer fields: error on negatives instead of wrapping
        // through `as u64` (e.g. "steps": -100 used to become 2^64 − 100…
        // truncated — either way nonsense).
        let u = |k: &str, dflt: u64| -> Result<u64, String> {
            match j.get(k) {
                None => Ok(dflt),
                Some(v) => {
                    let x = v
                        .as_f64()
                        .ok_or_else(|| format!("config key {k:?} must be a number"))?;
                    if !x.is_finite() || x < 0.0 || x.fract() != 0.0 {
                        return Err(format!(
                            "config key {k:?} must be a non-negative integer, got {x}"
                        ));
                    }
                    Ok(x as u64)
                }
            }
        };
        let f = |k: &str, dflt: f64| -> Result<f64, String> {
            match j.get(k) {
                None => Ok(dflt),
                Some(v) => v
                    .as_f64()
                    .ok_or_else(|| format!("config key {k:?} must be a number")),
            }
        };
        let algo_s = s("algo", base.algo.as_str())?;
        Ok(ExperimentConfig {
            name: s("name", &base.name)?,
            algo: Algo::parse(&algo_s).ok_or(format!("unknown algo {algo_s:?}"))?,
            nodes: u("nodes", base.nodes as u64)? as usize,
            topology: s("topology", &base.topology)?,
            topology_schedule: s("topology_schedule", &base.topology_schedule)?,
            link: s("link", &base.link)?,
            compressor: s("compressor", &base.compressor)?,
            trigger: s("trigger", &base.trigger)?,
            lr: s("lr", &base.lr)?,
            h: u("h", base.h)?,
            steps: u("steps", base.steps)?,
            eval_every: u("eval_every", base.eval_every)?,
            momentum: f("momentum", base.momentum)?,
            seed: u("seed", base.seed)?,
            problem: s("problem", &base.problem)?,
            gamma: f("gamma", base.gamma)?,
            workers: u("workers", base.workers as u64)? as usize,
        })
    }

    pub fn from_file(path: &str) -> Result<ExperimentConfig, String> {
        let text = std::fs::read_to_string(path).map_err(|e| e.to_string())?;
        let j = Json::parse(&text).map_err(|e| e.to_string())?;
        Self::from_json(&j)
    }
}

/// Presets mirroring the paper's experiments (scaled; DESIGN.md table).
pub mod presets {
    use super::*;

    /// Section 5.1 convex setting (synthetic MNIST, n = 60 ring, H = 5,
    /// SignTopK k = 10, trigger c₀ = 5000, η_t = 1/(t+100)).
    pub fn convex_sparq(steps: u64) -> ExperimentConfig {
        ExperimentConfig {
            name: "fig1-convex-sparq".into(),
            algo: Algo::Sparq,
            nodes: 60,
            topology: "ring".into(),
            topology_schedule: "static".into(),
            link: "none".into(),
            compressor: "sign_topk:10".into(),
            trigger: "const:5000".into(),
            lr: "invtime:100:1".into(),
            h: 5,
            steps,
            eval_every: 25, // fine-grained: early target crossings matter
            momentum: 0.0,
            seed: 42,
            problem: "logreg:784:10:5".into(),
            gamma: 0.0,
            workers: 1,
        }
    }

    /// Section 5.2 non-convex setting (synthetic CIFAR MLP, n = 8 ring,
    /// H = 5, SignTopK top-10%, piecewise trigger, momentum 0.9).
    pub fn nonconvex_sparq(steps: u64, steps_per_epoch: usize) -> ExperimentConfig {
        ExperimentConfig {
            name: "fig1-nonconvex-sparq".into(),
            algo: Algo::Sparq,
            nodes: 8,
            topology: "ring".into(),
            topology_schedule: "static".into(),
            link: "none".into(),
            compressor: "sign_topk:10%".into(),
            trigger: format!("piecewise:2.0:1.0:10:60:{steps_per_epoch}"),
            lr: format!("warmup:0.05:5:5:{steps_per_epoch}:150,250"),
            h: 5,
            steps,
            eval_every: (steps / 40).max(1),
            momentum: 0.9,
            seed: 42,
            problem: "mlp:3072:128:10:32".into(),
            gamma: 0.0,
            workers: 1,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_roundtrip() {
        let cfg = presets::convex_sparq(1000);
        let j = cfg.to_json();
        let back = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(cfg, back);
    }

    #[test]
    fn defaults_fill_missing_fields() {
        let j = Json::parse(r#"{"algo": "choco", "nodes": 12}"#).unwrap();
        let cfg = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(cfg.algo, Algo::Choco);
        assert_eq!(cfg.nodes, 12);
        assert_eq!(cfg.h, ExperimentConfig::default().h);
    }

    #[test]
    fn rejects_bad_algo() {
        let j = Json::parse(r#"{"algo": "magic"}"#).unwrap();
        assert!(ExperimentConfig::from_json(&j).is_err());
    }

    #[test]
    fn rejects_unknown_keys_with_listing() {
        let j = Json::parse(r#"{"trigerr": "const:100"}"#).unwrap();
        let err = ExperimentConfig::from_json(&j).unwrap_err();
        assert!(err.contains("trigerr"), "{err}");
        assert!(err.contains("trigger"), "listing missing: {err}");
        // non-object top level is an error too
        let j = Json::parse("[1, 2]").unwrap();
        assert!(ExperimentConfig::from_json(&j).is_err());
    }

    #[test]
    fn rejects_negative_unsigned_fields() {
        for bad in [
            r#"{"steps": -100}"#,
            r#"{"nodes": -1}"#,
            r#"{"h": -5}"#,
            r#"{"seed": -3}"#,
            r#"{"workers": -2}"#,
            r#"{"eval_every": -1}"#,
        ] {
            let j = Json::parse(bad).unwrap();
            let err = ExperimentConfig::from_json(&j).unwrap_err();
            assert!(err.contains("non-negative"), "{bad}: {err}");
        }
        // fractional values must not silently truncate through `as u64`
        let j = Json::parse(r#"{"steps": 2.9}"#).unwrap();
        assert!(ExperimentConfig::from_json(&j).is_err());
        let j = Json::parse(r#"{"steps": 100.0}"#).unwrap();
        assert_eq!(ExperimentConfig::from_json(&j).unwrap().steps, 100);
        // momentum/gamma are f64 fields — negatives there are allowed by
        // the parser (semantics are checked downstream)
        let j = Json::parse(r#"{"momentum": -0.5}"#).unwrap();
        assert!(ExperimentConfig::from_json(&j).is_ok());
    }

    #[test]
    fn rejects_wrong_types() {
        let j = Json::parse(r#"{"steps": "many"}"#).unwrap();
        assert!(ExperimentConfig::from_json(&j).is_err());
        let j = Json::parse(r#"{"trigger": 5}"#).unwrap();
        assert!(ExperimentConfig::from_json(&j).is_err());
    }

    #[test]
    fn new_scenario_fields_roundtrip() {
        let cfg = ExperimentConfig {
            topology_schedule: "switch:ring,torus:500".into(),
            link: "drop:0.1+straggler:0:0.5".into(),
            ..Default::default()
        };
        let back = ExperimentConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(cfg, back);
    }

    #[test]
    fn preset_specs_parse() {
        let cfg = presets::convex_sparq(100);
        assert!(crate::compress::parse(&cfg.compressor, 7850).is_some());
        assert!(crate::trigger::ThresholdSchedule::parse(&cfg.trigger).is_ok());
        assert!(crate::schedule::LrSchedule::parse(&cfg.lr).is_some());
        let cfg2 = presets::nonconvex_sparq(100, 50);
        assert!(crate::compress::parse(&cfg2.compressor, 394634).is_some());
        assert!(crate::trigger::ThresholdSchedule::parse(&cfg2.trigger).is_ok());
        assert!(crate::schedule::LrSchedule::parse(&cfg2.lr).is_some());
    }
}
