//! Typed experiment configuration (JSON in/out) + presets mirroring the
//! paper's Section 5 setups.
//!
//! Parse-don't-validate: every knob field is a typed spec value from
//! [`specs`] — constructed (and therefore validated) exactly once, at
//! the config boundary — rather than a raw `String` re-parsed by the
//! subsystem that happens to consume it. JSON input accepts both the
//! legacy string forms (`"compressor": "topk:100"`) and structured
//! objects (`"compressor": {"kind": "topk", "k": 100}`); output always
//! emits the canonical strings, so `config_hash` and sweep resume are
//! bit-compatible with the string-field era.
//!
//! Cross-field constraints live in [`ExperimentConfig::resolve`], which
//! produces the [`ResolvedConfig`] everything downstream (builders, the
//! [`Run`](crate::run::Run) handle, the sweep engine) consumes. All
//! failures are one structured [`ConfigError`].

pub mod error;
pub mod resolved;
pub mod specs;

pub use error::ConfigError;
pub use resolved::{GammaMode, ResolvedConfig};
pub use specs::{
    ClusterSpec, CompressorKind, CompressorSpec, Family, FamilySpec, FaultSpec, KSpec, LinkSpec,
    LrSpec, ProblemKind, ProblemSpec, ScheduleKindSpec, ScheduleSpec, SocketKind, SyncSpec,
    TopologySpec, TriggerSpec,
};

use crate::util::json::Json;

/// Which algorithm to instantiate.
#[derive(Clone, Debug, PartialEq)]
pub enum Algo {
    Sparq,
    Choco,
    Vanilla,
}

impl Algo {
    pub fn parse(s: &str) -> Option<Algo> {
        match s {
            "sparq" => Some(Algo::Sparq),
            "choco" => Some(Algo::Choco),
            "vanilla" => Some(Algo::Vanilla),
            _ => None,
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            Algo::Sparq => "sparq",
            Algo::Choco => "choco",
            Algo::Vanilla => "vanilla",
        }
    }
}

/// Full experiment description. Every knob field is a typed spec (see
/// module docs); scalars stay scalars. Construct via JSON
/// ([`from_json`](Self::from_json) / [`from_file`](Self::from_file)),
/// struct literals with the typed constructors (or `"spec".into()`,
/// which panics on an invalid literal), then call
/// [`resolve`](Self::resolve) for the cross-field-checked form.
#[derive(Clone, Debug, PartialEq)]
pub struct ExperimentConfig {
    pub name: String,
    pub algo: Algo,
    pub nodes: usize,
    /// Communication graph (ignored when `topology_schedule` is
    /// non-static — the schedule names its own graphs).
    pub topology: TopologySpec,
    /// Time-varying topology schedule; `ScheduleSpec::fixed()` (the
    /// default) keeps `topology` in force for the whole run.
    pub topology_schedule: ScheduleSpec,
    /// Link-fault model (`LinkSpec::ideal()` = the loss-free default).
    pub link: LinkSpec,
    /// Scheduled fault plan: node crashes, partitions, corruption
    /// (`FaultSpec::none()` = the default; composes with `link`).
    /// Omitted from the JSON form when default, so pre-fault configs
    /// hash identically.
    pub fault: FaultSpec,
    /// Algorithm family for the event-triggered engine: `sparq` (the
    /// default) or `squarm:BETA` (momentum-buffered trigger drift).
    /// Only meaningful with `algo = sparq` (checked by `resolve`).
    /// Omitted from the JSON form when default, so pre-family configs
    /// hash identically.
    pub family: FamilySpec,
    /// Multi-process deployment knobs for `sparq cluster` (socket kind,
    /// lease/heartbeat/connect timings). Pure deployment — it cannot
    /// change what the run computes, so `config_hash` normalizes it away
    /// and the JSON form omits it when default.
    pub cluster: ClusterSpec,
    pub compressor: CompressorSpec,
    pub trigger: TriggerSpec,
    pub lr: LrSpec,
    /// Synchronization schedule I_T. Legacy configs write the period as
    /// a bare number (`"h": 5` = sync every 5 iterations); explicit
    /// index sets are also expressible (`"h": "explicit:3,5,10"`).
    pub h: SyncSpec,
    pub steps: u64,
    pub eval_every: u64,
    pub momentum: f64,
    pub seed: u64,
    pub problem: ProblemSpec,
    /// Consensus step size γ: > 0 pins the value, 0 ⇒ tuned heuristic
    /// (`SpectralInfo::gamma_tuned`), < 0 pins γ = 0 exactly (mixing
    /// disabled — the ablation diagnostic; plain 0 cannot mean that
    /// because it is the unset default).
    pub gamma: f64,
    /// Worker threads for the coordinator's per-node phases (1 ⇒
    /// sequential, 0 ⇒ available CPUs); bit-for-bit deterministic across
    /// values.
    pub workers: usize,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            name: "default".into(),
            algo: Algo::Sparq,
            nodes: 8,
            topology: TopologySpec::ring(),
            topology_schedule: ScheduleSpec::fixed(),
            link: LinkSpec::ideal(),
            fault: FaultSpec::none(),
            family: FamilySpec::sparq(),
            cluster: ClusterSpec::uds(),
            compressor: CompressorSpec::sign_top_k_pct(10.0),
            trigger: TriggerSpec::constant(100.0),
            lr: LrSpec::inv_time(100.0, 1.0),
            h: SyncSpec::every(5),
            steps: 1000,
            eval_every: 50,
            momentum: 0.0,
            seed: 42,
            problem: ProblemSpec::quadratic(64),
            gamma: 0.0,
            workers: 1,
        }
    }
}

impl ExperimentConfig {
    pub fn to_json(&self) -> Json {
        let j = Json::obj()
            .set("name", self.name.as_str())
            .set("algo", self.algo.as_str())
            .set("nodes", self.nodes)
            .set("topology", self.topology.to_json())
            .set("topology_schedule", self.topology_schedule.to_json())
            .set("link", self.link.to_json())
            .set("compressor", self.compressor.to_json())
            .set("trigger", self.trigger.to_json())
            .set("lr", self.lr.to_json())
            .set("h", self.h.to_json())
            .set("steps", self.steps)
            .set("eval_every", self.eval_every)
            .set("momentum", self.momentum)
            .set("seed", self.seed)
            .set("problem", self.problem.to_json())
            .set("gamma", self.gamma)
            .set("workers", self.workers);
        // Emitted only when set: pre-fault / pre-family configs keep
        // their exact serialized bytes, so config_hash / sweep resume
        // ids are unchanged (pinned by rust/tests/config_golden.rs).
        let j = if self.fault.is_none() {
            j
        } else {
            j.set("fault", self.fault.to_json())
        };
        let j = if self.family.is_default() {
            j
        } else {
            j.set("family", self.family.to_json())
        };
        if self.cluster.is_default() {
            j
        } else {
            j.set("cluster", self.cluster.to_json())
        }
    }

    /// Every key `from_json` understands (used for typo rejection).
    pub const KEYS: &[&str] = &[
        "name",
        "algo",
        "nodes",
        "topology",
        "topology_schedule",
        "link",
        "compressor",
        "trigger",
        "lr",
        "h",
        "fault",
        "family",
        "cluster",
        "steps",
        "eval_every",
        "momentum",
        "seed",
        "problem",
        "gamma",
        "workers",
    ];

    pub fn from_json(j: &Json) -> Result<ExperimentConfig, ConfigError> {
        let obj = j.as_obj().ok_or_else(|| ConfigError::Shape {
            reason: "config must be a JSON object".into(),
        })?;
        // Reject unknown keys: a typo ("trigerr") must not silently fall
        // back to the default schedule.
        for key in obj.keys() {
            if !Self::KEYS.contains(&key.as_str()) {
                return Err(ConfigError::UnknownKey {
                    key: key.clone(),
                    valid: Self::KEYS.iter().map(|k| k.to_string()).collect(),
                });
            }
        }
        let base = ExperimentConfig::default();
        let s = |k: &str, dflt: &str| -> Result<String, ConfigError> {
            match j.get(k) {
                None => Ok(dflt.to_string()),
                Some(v) => v.as_str().map(str::to_string).ok_or_else(|| {
                    ConfigError::value(k, v.to_string(), "must be a string")
                }),
            }
        };
        // Unsigned integer fields: error on negatives instead of wrapping
        // through `as u64` (e.g. "steps": -100 used to become 2^64 − 100…
        // truncated — either way nonsense).
        let u = |k: &str, dflt: u64| -> Result<u64, ConfigError> {
            match j.get(k) {
                None => Ok(dflt),
                Some(v) => {
                    let x = v.as_f64().ok_or_else(|| {
                        ConfigError::value(k, v.to_string(), "must be a number")
                    })?;
                    if !x.is_finite() || x < 0.0 || x.fract() != 0.0 {
                        return Err(ConfigError::value(
                            k,
                            v.to_string(),
                            format!("must be a non-negative integer, got {x}"),
                        ));
                    }
                    Ok(x as u64)
                }
            }
        };
        let f = |k: &str, dflt: f64| -> Result<f64, ConfigError> {
            match j.get(k) {
                None => Ok(dflt),
                Some(v) => v.as_f64().ok_or_else(|| {
                    ConfigError::value(k, v.to_string(), "must be a number")
                }),
            }
        };
        // Typed spec fields: accept the legacy string or the structured
        // object form; default when absent.
        fn spec<T>(
            j: &Json,
            k: &str,
            dflt: &T,
            parse: impl Fn(&Json) -> Result<T, ConfigError>,
        ) -> Result<T, ConfigError>
        where
            T: Clone,
        {
            match j.get(k) {
                None => Ok(dflt.clone()),
                Some(v) => parse(v),
            }
        }
        let algo_s = s("algo", base.algo.as_str())?;
        Ok(ExperimentConfig {
            name: s("name", &base.name)?,
            algo: Algo::parse(&algo_s).ok_or_else(|| {
                ConfigError::value("algo", &algo_s, "unknown algo")
                    .suggest("sparq, choco, or vanilla")
            })?,
            nodes: u("nodes", base.nodes as u64)? as usize,
            topology: spec(j, "topology", &base.topology, TopologySpec::from_json)?,
            topology_schedule: spec(
                j,
                "topology_schedule",
                &base.topology_schedule,
                ScheduleSpec::from_json,
            )?,
            link: spec(j, "link", &base.link, LinkSpec::from_json)?,
            fault: spec(j, "fault", &base.fault, FaultSpec::from_json)?,
            family: spec(j, "family", &base.family, FamilySpec::from_json)?,
            cluster: spec(j, "cluster", &base.cluster, ClusterSpec::from_json)?,
            compressor: spec(j, "compressor", &base.compressor, CompressorSpec::from_json)?,
            trigger: spec(j, "trigger", &base.trigger, TriggerSpec::from_json)?,
            lr: spec(j, "lr", &base.lr, LrSpec::from_json)?,
            h: spec(j, "h", &base.h, SyncSpec::from_json)?,
            steps: u("steps", base.steps)?,
            eval_every: u("eval_every", base.eval_every)?,
            momentum: f("momentum", base.momentum)?,
            seed: u("seed", base.seed)?,
            problem: spec(j, "problem", &base.problem, ProblemSpec::from_json)?,
            gamma: f("gamma", base.gamma)?,
            workers: u("workers", base.workers as u64)? as usize,
        })
    }

    pub fn from_file(path: &str) -> Result<ExperimentConfig, ConfigError> {
        let text = std::fs::read_to_string(path).map_err(|e| ConfigError::Shape {
            reason: format!("{path}: {e}"),
        })?;
        let j = Json::parse(&text).map_err(|e| ConfigError::Shape {
            reason: format!("{path}: {e}"),
        })?;
        Self::from_json(&j)
    }
}

/// Presets mirroring the paper's experiments (scaled; DESIGN.md table).
pub mod presets {
    use super::*;

    /// Section 5.1 convex setting (synthetic MNIST, n = 60 ring, H = 5,
    /// SignTopK k = 10, trigger c₀ = 5000, η_t = 1/(t+100)).
    pub fn convex_sparq(steps: u64) -> ExperimentConfig {
        ExperimentConfig {
            name: "fig1-convex-sparq".into(),
            algo: Algo::Sparq,
            nodes: 60,
            compressor: CompressorSpec::sign_top_k(10),
            trigger: TriggerSpec::constant(5000.0),
            lr: LrSpec::inv_time(100.0, 1.0),
            h: SyncSpec::every(5),
            steps,
            eval_every: 25, // fine-grained: early target crossings matter
            momentum: 0.0,
            seed: 42,
            problem: ProblemSpec::logreg(784, 10, 5),
            ..Default::default()
        }
    }

    /// Section 5.2 non-convex setting (synthetic CIFAR MLP, n = 8 ring,
    /// H = 5, SignTopK top-10%, piecewise trigger, momentum 0.9).
    pub fn nonconvex_sparq(steps: u64, steps_per_epoch: usize) -> ExperimentConfig {
        ExperimentConfig {
            name: "fig1-nonconvex-sparq".into(),
            algo: Algo::Sparq,
            nodes: 8,
            compressor: CompressorSpec::sign_top_k_pct(10.0),
            // Float spellings ("2.0") preserved verbatim — the canonical
            // string is part of the config hash.
            trigger: format!("piecewise:2.0:1.0:10:60:{steps_per_epoch}").into(),
            lr: format!("warmup:0.05:5:5:{steps_per_epoch}:150,250").into(),
            h: SyncSpec::every(5),
            steps,
            eval_every: (steps / 40).max(1),
            momentum: 0.9,
            seed: 42,
            problem: ProblemSpec::mlp(3072, 128, 10, 32),
            ..Default::default()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_roundtrip() {
        let cfg = presets::convex_sparq(1000);
        let j = cfg.to_json();
        let back = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(cfg, back);
    }

    #[test]
    fn defaults_fill_missing_fields() {
        let j = Json::parse(r#"{"algo": "choco", "nodes": 12}"#).unwrap();
        let cfg = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(cfg.algo, Algo::Choco);
        assert_eq!(cfg.nodes, 12);
        assert_eq!(cfg.h, ExperimentConfig::default().h);
    }

    #[test]
    fn rejects_bad_algo() {
        let j = Json::parse(r#"{"algo": "magic"}"#).unwrap();
        assert!(ExperimentConfig::from_json(&j).is_err());
    }

    #[test]
    fn rejects_unknown_keys_with_listing() {
        let j = Json::parse(r#"{"trigerr": "const:100"}"#).unwrap();
        let err = ExperimentConfig::from_json(&j).unwrap_err().to_string();
        assert!(err.contains("trigerr"), "{err}");
        assert!(err.contains("trigger"), "listing missing: {err}");
        // non-object top level is an error too
        let j = Json::parse("[1, 2]").unwrap();
        assert!(ExperimentConfig::from_json(&j).is_err());
    }

    #[test]
    fn rejects_negative_unsigned_fields() {
        for bad in [
            r#"{"steps": -100}"#,
            r#"{"nodes": -1}"#,
            r#"{"h": -5}"#,
            r#"{"seed": -3}"#,
            r#"{"workers": -2}"#,
            r#"{"eval_every": -1}"#,
        ] {
            let j = Json::parse(bad).unwrap();
            let err = ExperimentConfig::from_json(&j).unwrap_err().to_string();
            assert!(err.contains("non-negative"), "{bad}: {err}");
        }
        // fractional values must not silently truncate through `as u64`
        let j = Json::parse(r#"{"steps": 2.9}"#).unwrap();
        assert!(ExperimentConfig::from_json(&j).is_err());
        let j = Json::parse(r#"{"steps": 100.0}"#).unwrap();
        assert_eq!(ExperimentConfig::from_json(&j).unwrap().steps, 100);
        // momentum/gamma are f64 fields — negatives there are allowed by
        // the parser (semantics are checked at resolve())
        let j = Json::parse(r#"{"momentum": -0.5}"#).unwrap();
        assert!(ExperimentConfig::from_json(&j).is_ok());
    }

    #[test]
    fn rejects_wrong_types() {
        let j = Json::parse(r#"{"steps": "many"}"#).unwrap();
        assert!(ExperimentConfig::from_json(&j).is_err());
        let j = Json::parse(r#"{"trigger": 5}"#).unwrap();
        assert!(ExperimentConfig::from_json(&j).is_err());
    }

    #[test]
    fn invalid_specs_fail_at_parse_time_with_the_field_named() {
        for (body, field) in [
            (r#"{"trigger": "poly:2:1.5"}"#, "trigger"),
            (r#"{"compressor": "topk:0"}"#, "compressor"),
            (r#"{"lr": "const:fast"}"#, "lr"),
            (r#"{"link": "drop:2"}"#, "link"),
            (r#"{"topology": "moebius"}"#, "topology"),
            (r#"{"topology_schedule": "switch:ring:0"}"#, "topology_schedule"),
            (r#"{"problem": "svm:1"}"#, "problem"),
            (r#"{"h": "explicit:5,3"}"#, "h"),
        ] {
            let j = Json::parse(body).unwrap();
            let err = ExperimentConfig::from_json(&j).unwrap_err();
            assert_eq!(err.field(), Some(field), "{body}: {err}");
        }
    }

    #[test]
    fn structured_object_fields_parse_alongside_strings() {
        let j = Json::parse(
            r#"{
                "compressor": {"kind": "sign_topk", "k": "10%"},
                "trigger": {"kind": "const", "c0": 100},
                "lr": {"kind": "invtime", "a": 100, "b": 1},
                "problem": {"kind": "quadratic", "d": 64}
            }"#,
        )
        .unwrap();
        let cfg = ExperimentConfig::from_json(&j).unwrap();
        // object forms canonicalize to the default config's strings, so
        // the whole config is the default (name aside)
        assert_eq!(cfg, ExperimentConfig::default());
        // and hashes identically to the string-form config
        assert_eq!(cfg.to_json().to_string(), ExperimentConfig::default().to_json().to_string());
    }

    #[test]
    fn new_scenario_fields_roundtrip() {
        let cfg = ExperimentConfig {
            topology_schedule: "switch:ring,torus:500".into(),
            link: "drop:0.1+straggler:0:0.5".into(),
            ..Default::default()
        };
        let back = ExperimentConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(cfg, back);
    }

    #[test]
    fn fault_field_roundtrips_but_defaults_stay_byte_identical() {
        // default plan ⇒ no "fault" key in the JSON (hash compatibility)
        let dflt = ExperimentConfig::default();
        assert!(!dflt.to_json().to_string().contains("fault"));
        // set plan ⇒ emitted, and roundtrips
        let cfg = ExperimentConfig {
            fault: "crash:1:100:200+corrupt:0.01".into(),
            ..Default::default()
        };
        let text = cfg.to_json().to_string();
        assert!(text.contains(r#""fault":"crash:1:100:200+corrupt:0.01""#), "{text}");
        let back = ExperimentConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(cfg, back);
        // explicit "none" parses to the default (and re-serializes away)
        let j = Json::parse(r#"{"fault": "none"}"#).unwrap();
        let cfg = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(cfg, ExperimentConfig::default());
        // invalid plans fail at the boundary with the field named
        let j = Json::parse(r#"{"fault": "crash:0:9:3"}"#).unwrap();
        let err = ExperimentConfig::from_json(&j).unwrap_err();
        assert_eq!(err.field(), Some("fault"), "{err}");
    }

    #[test]
    fn family_field_roundtrips_but_defaults_stay_byte_identical() {
        // default family ⇒ no "family" key in the JSON (hash compatibility)
        let dflt = ExperimentConfig::default();
        assert!(!dflt.to_json().to_string().contains("family"));
        // squarm ⇒ emitted, and roundtrips
        let cfg = ExperimentConfig {
            family: "squarm:0.9".into(),
            ..Default::default()
        };
        let text = cfg.to_json().to_string();
        assert!(text.contains(r#""family":"squarm:0.9""#), "{text}");
        let back = ExperimentConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(cfg, back);
        // explicit "sparq" parses to the default (and re-serializes away)
        let j = Json::parse(r#"{"family": "sparq"}"#).unwrap();
        let cfg = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(cfg, ExperimentConfig::default());
        assert!(!cfg.to_json().to_string().contains("family"));
        // invalid families fail at the boundary with the field named
        let j = Json::parse(r#"{"family": "squarm:2"}"#).unwrap();
        let err = ExperimentConfig::from_json(&j).unwrap_err();
        assert_eq!(err.field(), Some("family"), "{err}");
        // the structured object form works through the config too
        let j = Json::parse(r#"{"family": {"kind": "squarm", "beta": 0.5}}"#).unwrap();
        let cfg = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(cfg.family.as_str(), "squarm:0.5");
    }

    #[test]
    fn cluster_field_roundtrips_but_defaults_stay_byte_identical() {
        // default deployment ⇒ no "cluster" key (hash compatibility)
        let dflt = ExperimentConfig::default();
        assert!(!dflt.to_json().to_string().contains("cluster"));
        // non-default ⇒ emitted, and roundtrips
        let cfg = ExperimentConfig {
            cluster: "tcp@127.0.0.1:8:2".into(),
            ..Default::default()
        };
        let text = cfg.to_json().to_string();
        assert!(text.contains(r#""cluster":"tcp@127.0.0.1:8:2""#), "{text}");
        let back = ExperimentConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(cfg, back);
        // explicit "uds" parses to the default (and re-serializes away)
        let j = Json::parse(r#"{"cluster": "uds"}"#).unwrap();
        let cfg = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(cfg, ExperimentConfig::default());
        // invalid specs fail at the boundary with the field named
        let j = Json::parse(r#"{"cluster": "udp"}"#).unwrap();
        let err = ExperimentConfig::from_json(&j).unwrap_err();
        assert_eq!(err.field(), Some("cluster"), "{err}");
        // deployment must not change the run identity
        let deployed = ExperimentConfig {
            cluster: "tcp:9:3".into(),
            ..Default::default()
        };
        assert_eq!(
            crate::sweep::spec::config_hash(&deployed),
            crate::sweep::spec::config_hash(&ExperimentConfig::default()),
        );
    }

    #[test]
    fn randomized_sync_spec_roundtrips_through_config() {
        // The Section 2 randomized-I_T ablation: the raw spec string is
        // preserved through serialization, and re-parsing expands to the
        // identical explicit index set (seeded, deterministic).
        let cfg = ExperimentConfig {
            h: "random:5:1000:42".into(),
            ..Default::default()
        };
        assert_eq!(cfg.h.period(), None);
        let back = ExperimentConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(cfg, back);
        assert_eq!(back.h.as_str(), "random:5:1000:42");
        assert_eq!(cfg.h.schedule(), back.h.schedule());
    }

    #[test]
    fn preset_specs_are_typed_and_buildable() {
        let cfg = presets::convex_sparq(100);
        assert_eq!(cfg.compressor.build(7850).name(), "sign_topk(k=10)");
        assert_eq!(cfg.problem.dim(), 7850);
        assert!(cfg.resolve().is_ok());
        let cfg2 = presets::nonconvex_sparq(100, 50);
        assert_eq!(cfg2.problem.dim(), 394634);
        assert!(cfg2.resolve().is_ok());
    }
}
