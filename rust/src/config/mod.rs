//! Typed experiment configuration (JSON in/out) + presets mirroring the
//! paper's Section 5 setups.

use crate::util::json::Json;

/// Which algorithm to instantiate.
#[derive(Clone, Debug, PartialEq)]
pub enum Algo {
    Sparq,
    Choco,
    Vanilla,
}

impl Algo {
    pub fn parse(s: &str) -> Option<Algo> {
        match s {
            "sparq" => Some(Algo::Sparq),
            "choco" => Some(Algo::Choco),
            "vanilla" => Some(Algo::Vanilla),
            _ => None,
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            Algo::Sparq => "sparq",
            Algo::Choco => "choco",
            Algo::Vanilla => "vanilla",
        }
    }
}

/// Full experiment description. String-spec fields use the module parsers
/// (`compress::parse`, `ThresholdSchedule::parse`, `LrSchedule::parse`,
/// `TopologyKind::parse`) so configs stay flat and diff-friendly.
#[derive(Clone, Debug, PartialEq)]
pub struct ExperimentConfig {
    pub name: String,
    pub algo: Algo,
    pub nodes: usize,
    pub topology: String,
    pub compressor: String,
    pub trigger: String,
    pub lr: String,
    /// Sync period H.
    pub h: u64,
    pub steps: u64,
    pub eval_every: u64,
    pub momentum: f64,
    pub seed: u64,
    /// Problem spec: "quadratic:D", "logreg:DIN:CLASSES:BATCH",
    /// "mlp:DIN:HIDDEN:CLASSES:BATCH".
    pub problem: String,
    /// Override consensus γ (0 ⇒ Lemma-6 γ*).
    pub gamma: f64,
    /// Worker threads for the coordinator's per-node phases (1 ⇒
    /// sequential, 0 ⇒ available CPUs); bit-for-bit deterministic across
    /// values.
    pub workers: usize,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            name: "default".into(),
            algo: Algo::Sparq,
            nodes: 8,
            topology: "ring".into(),
            compressor: "sign_topk:10%".into(),
            trigger: "const:100".into(),
            lr: "invtime:100:1".into(),
            h: 5,
            steps: 1000,
            eval_every: 50,
            momentum: 0.0,
            seed: 42,
            problem: "quadratic:64".into(),
            gamma: 0.0,
            workers: 1,
        }
    }
}

impl ExperimentConfig {
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("name", self.name.as_str())
            .set("algo", self.algo.as_str())
            .set("nodes", self.nodes)
            .set("topology", self.topology.as_str())
            .set("compressor", self.compressor.as_str())
            .set("trigger", self.trigger.as_str())
            .set("lr", self.lr.as_str())
            .set("h", self.h)
            .set("steps", self.steps)
            .set("eval_every", self.eval_every)
            .set("momentum", self.momentum)
            .set("seed", self.seed)
            .set("problem", self.problem.as_str())
            .set("gamma", self.gamma)
            .set("workers", self.workers)
    }

    pub fn from_json(j: &Json) -> Result<ExperimentConfig, String> {
        let base = ExperimentConfig::default();
        let s = |k: &str, dflt: &str| -> String {
            j.get(k)
                .and_then(Json::as_str)
                .unwrap_or(dflt)
                .to_string()
        };
        let u = |k: &str, dflt: u64| j.get(k).and_then(Json::as_f64).map(|x| x as u64).unwrap_or(dflt);
        let f = |k: &str, dflt: f64| j.get(k).and_then(Json::as_f64).unwrap_or(dflt);
        let algo_s = s("algo", base.algo.as_str());
        Ok(ExperimentConfig {
            name: s("name", &base.name),
            algo: Algo::parse(&algo_s).ok_or(format!("unknown algo {algo_s:?}"))?,
            nodes: u("nodes", base.nodes as u64) as usize,
            topology: s("topology", &base.topology),
            compressor: s("compressor", &base.compressor),
            trigger: s("trigger", &base.trigger),
            lr: s("lr", &base.lr),
            h: u("h", base.h),
            steps: u("steps", base.steps),
            eval_every: u("eval_every", base.eval_every),
            momentum: f("momentum", base.momentum),
            seed: u("seed", base.seed),
            problem: s("problem", &base.problem),
            gamma: f("gamma", base.gamma),
            workers: u("workers", base.workers as u64) as usize,
        })
    }

    pub fn from_file(path: &str) -> Result<ExperimentConfig, String> {
        let text = std::fs::read_to_string(path).map_err(|e| e.to_string())?;
        let j = Json::parse(&text).map_err(|e| e.to_string())?;
        Self::from_json(&j)
    }
}

/// Presets mirroring the paper's experiments (scaled; DESIGN.md table).
pub mod presets {
    use super::*;

    /// Section 5.1 convex setting (synthetic MNIST, n = 60 ring, H = 5,
    /// SignTopK k = 10, trigger c₀ = 5000, η_t = 1/(t+100)).
    pub fn convex_sparq(steps: u64) -> ExperimentConfig {
        ExperimentConfig {
            name: "fig1-convex-sparq".into(),
            algo: Algo::Sparq,
            nodes: 60,
            topology: "ring".into(),
            compressor: "sign_topk:10".into(),
            trigger: "const:5000".into(),
            lr: "invtime:100:1".into(),
            h: 5,
            steps,
            eval_every: 25, // fine-grained: early target crossings matter
            momentum: 0.0,
            seed: 42,
            problem: "logreg:784:10:5".into(),
            gamma: 0.0,
            workers: 1,
        }
    }

    /// Section 5.2 non-convex setting (synthetic CIFAR MLP, n = 8 ring,
    /// H = 5, SignTopK top-10%, piecewise trigger, momentum 0.9).
    pub fn nonconvex_sparq(steps: u64, steps_per_epoch: usize) -> ExperimentConfig {
        ExperimentConfig {
            name: "fig1-nonconvex-sparq".into(),
            algo: Algo::Sparq,
            nodes: 8,
            topology: "ring".into(),
            compressor: "sign_topk:10%".into(),
            trigger: format!("piecewise:2.0:1.0:10:60:{steps_per_epoch}"),
            lr: format!("warmup:0.05:5:5:{steps_per_epoch}:150,250"),
            h: 5,
            steps,
            eval_every: (steps / 40).max(1),
            momentum: 0.9,
            seed: 42,
            problem: "mlp:3072:128:10:32".into(),
            gamma: 0.0,
            workers: 1,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_roundtrip() {
        let cfg = presets::convex_sparq(1000);
        let j = cfg.to_json();
        let back = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(cfg, back);
    }

    #[test]
    fn defaults_fill_missing_fields() {
        let j = Json::parse(r#"{"algo": "choco", "nodes": 12}"#).unwrap();
        let cfg = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(cfg.algo, Algo::Choco);
        assert_eq!(cfg.nodes, 12);
        assert_eq!(cfg.h, ExperimentConfig::default().h);
    }

    #[test]
    fn rejects_bad_algo() {
        let j = Json::parse(r#"{"algo": "magic"}"#).unwrap();
        assert!(ExperimentConfig::from_json(&j).is_err());
    }

    #[test]
    fn preset_specs_parse() {
        let cfg = presets::convex_sparq(100);
        assert!(crate::compress::parse(&cfg.compressor, 7850).is_some());
        assert!(crate::trigger::ThresholdSchedule::parse(&cfg.trigger).is_some());
        assert!(crate::schedule::LrSchedule::parse(&cfg.lr).is_some());
        let cfg2 = presets::nonconvex_sparq(100, 50);
        assert!(crate::compress::parse(&cfg2.compressor, 394634).is_some());
        assert!(crate::trigger::ThresholdSchedule::parse(&cfg2.trigger).is_some());
        assert!(crate::schedule::LrSchedule::parse(&cfg2.lr).is_some());
    }
}
