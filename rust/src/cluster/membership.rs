//! Cluster membership on the heartbeat-lease claim store.
//!
//! Each node process holds the claim `node-<rank>` in the store at
//! `<dir>/membership/claims/`, heartbeating it from the engine's
//! observer tick. The launcher treats the claim set as the membership
//! view: it waits for all N claims before calling the cluster formed
//! (join detection), and deletes a claim after `SIGKILL`ing its
//! process so the respawned node can re-acquire immediately instead of
//! waiting out the lease. A node that loses its lease mid-run learns it
//! from the heartbeat return value — someone else owns its rank, so it
//! must stop rather than fight over sockets.

use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use crate::sweep::distributed::{
    default_owner, list_claims, now_secs, Acquire, Claim, ClaimInfo, ClaimStore,
};

const POLL: Duration = Duration::from_millis(50);

/// The claim id for a rank.
pub fn claim_id(rank: usize) -> String {
    format!("node-{rank}")
}

/// Where rank `rank`'s claim file lives under a cluster directory (the
/// launcher deletes this after a kill).
pub fn claim_file(dir: &Path, rank: usize) -> PathBuf {
    dir.join("membership")
        .join("claims")
        .join(format!("{}.claim", claim_id(rank)))
}

/// One node's held membership: the claim plus a rate limiter so the
/// per-step observer tick can call [`Membership::beat`] unconditionally.
pub struct Membership {
    claim: Option<Claim>,
    heartbeat: Duration,
    last_beat: Instant,
}

impl Membership {
    /// Acquire `node-<rank>`, retrying until `deadline` (the previous
    /// incarnation's claim may still be on disk until the launcher
    /// deletes it or the lease expires).
    pub fn join(
        dir: &Path,
        rank: usize,
        lease_secs: f64,
        heartbeat_secs: f64,
        deadline: Duration,
    ) -> Result<Membership, String> {
        let store = ClaimStore::new(
            dir.join("membership").join("claims"),
            default_owner(),
            lease_secs,
        )?;
        let id = claim_id(rank);
        let until = Instant::now() + deadline;
        loop {
            match store.try_acquire(&id)? {
                Acquire::Acquired(claim) => {
                    return Ok(Membership {
                        claim: Some(claim),
                        heartbeat: Duration::from_secs_f64(heartbeat_secs.max(0.01)),
                        last_beat: Instant::now(),
                    })
                }
                Acquire::Held => {
                    if Instant::now() >= until {
                        return Err(format!(
                            "rank {rank}: claim {id:?} still held after {deadline:?}"
                        ));
                    }
                    std::thread::sleep(POLL);
                }
            }
        }
    }

    /// Heartbeat if a heartbeat interval has passed; cheap to call every
    /// step. `Ok(false)` means the lease was taken over (or the claim
    /// vanished) — this process no longer owns its rank.
    pub fn beat(&mut self) -> Result<bool, String> {
        if self.last_beat.elapsed() < self.heartbeat {
            return Ok(true);
        }
        self.last_beat = Instant::now();
        match self.claim.as_mut() {
            Some(c) => c.heartbeat(),
            None => Ok(false),
        }
    }

    /// Release the claim (normal exit).
    pub fn leave(mut self) -> Result<(), String> {
        match self.claim.take() {
            Some(c) => c.release(),
            None => Ok(()),
        }
    }
}

/// The current membership view: claims present under
/// `<dir>/membership/claims/`.
pub fn view(dir: &Path) -> Result<Vec<ClaimInfo>, String> {
    list_claims(&dir.join("membership"), now_secs())
}

/// Block until all `n` ranks hold their claims (cluster formed), or
/// fail after `timeout`. Returns the number of distinct ranks seen on
/// failure for the error message.
pub fn wait_for_cluster(dir: &Path, n: usize, timeout: Duration) -> Result<(), String> {
    let until = Instant::now() + timeout;
    loop {
        let seen = view(dir)?
            .iter()
            .filter(|c| (0..n).any(|r| c.id == claim_id(r)))
            .count();
        if seen == n {
            return Ok(());
        }
        if Instant::now() >= until {
            return Err(format!(
                "cluster did not form: {seen}/{n} membership claims after {timeout:?}"
            ));
        }
        std::thread::sleep(POLL);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("sparq-member-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).expect("mkdir");
        d
    }

    #[test]
    fn join_beat_view_leave_round_trip() {
        let dir = tmp_dir("join");
        let mut m0 =
            Membership::join(&dir, 0, 5.0, 0.0, Duration::from_secs(1)).expect("join 0");
        let m1 = Membership::join(&dir, 1, 5.0, 0.0, Duration::from_secs(1)).expect("join 1");
        wait_for_cluster(&dir, 2, Duration::from_secs(1)).expect("formed");
        assert!(m0.beat().expect("beat"));
        assert_eq!(view(&dir).expect("view").len(), 2);
        m1.leave().expect("leave");
        let err = wait_for_cluster(&dir, 2, Duration::from_millis(120)).unwrap_err();
        assert!(err.contains("1/2"), "{err}");
        m0.leave().expect("leave");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn a_held_rank_blocks_rejoin_until_its_claim_is_deleted() {
        let dir = tmp_dir("held");
        let m = Membership::join(&dir, 3, 30.0, 1.0, Duration::from_secs(1)).expect("join");
        let err =
            Membership::join(&dir, 3, 30.0, 1.0, Duration::from_millis(150)).unwrap_err();
        assert!(err.contains("node-3"), "{err}");
        // The launcher's post-SIGKILL cleanup: delete the claim file.
        std::fs::remove_file(claim_file(&dir, 3)).expect("delete claim");
        let m2 = Membership::join(&dir, 3, 30.0, 1.0, Duration::from_secs(1))
            .expect("rejoin after cleanup");
        // The old incarnation's lease is gone: its heartbeat reports the
        // takeover instead of silently fighting.
        drop(m);
        m2.leave().expect("leave");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
