//! One cluster node process: the full deterministic engine behind a
//! socket transport.
//!
//! Every rank runs the *complete* n-node engine (SPMD full replica):
//! seeded coins, triggers, stragglers, and fault windows are replicated
//! computation, so each process independently knows who fires and who
//! is down at every round — no control messages exist. The only bytes
//! that travel are each rank's own broadcasts (see
//! [`super::socket::SocketTransport`]). Bit-identity to the in-process
//! engine follows: substitution of a received frame is a lossless
//! round trip, and everything else *is* the in-process engine.
//!
//! Crash windows in the fault plan become real process deaths. When a
//! rank reaches the start of one of its own windows it checkpoints at
//! exactly `t = down` (the cadence-independent boundary the rejoin
//! restores from), writes a kill marker under `<dir>/kill/`, and parks
//! — the launcher `SIGKILL`s it, deletes its membership claim, and
//! respawns it with `--mute-until up`. The respawn restores the
//! checkpoint and replays `[down, up)` with the transport muted (the
//! node is down in every replica's plan, so no peer addresses it), then
//! rejoins live traffic at `t = up`. Resync accounting is the engine's
//! own replicated `fault_transition` — identical to in-process.

use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use super::membership::{self, Membership};
use super::socket::{write_atomic, Links, SocketTransport, StatsHandle};
use crate::comm::fault::CrashWindow;
use crate::config::ExperimentConfig;
use crate::coordinator::{Checkpoint, DecentralizedAlgo};
use crate::metrics::Series;
use crate::run::{DriveEnd, Run, RunObserver};
use crate::sweep::spec::config_hash;
use crate::util::json::Json;

/// How long a parked (kill-marked) node waits for its `SIGKILL` before
/// concluding the launcher died and exiting with an error.
const PARK_CAP: Duration = Duration::from_secs(600);

/// Everything a node process needs (the launcher passes these as
/// `cluster-node` flags).
pub struct NodeOptions {
    pub rank: usize,
    /// The shared cluster directory.
    pub dir: PathBuf,
    pub cfg: ExperimentConfig,
    /// Checkpoint cadence in iterations (0 = only at crash boundaries).
    pub checkpoint_every: u64,
    /// Replay `[restore_t, mute_until)` with the transport silent
    /// (rejoin path; 0 for a fresh start).
    pub mute_until: u64,
    /// Ignore own crash windows starting before this iteration (they
    /// were already served by a previous incarnation).
    pub min_crash_start: u64,
    pub verbose: bool,
}

/// Canonical series fingerprint: FNV-64 over the records' exact bit
/// patterns (`f64::to_bits`, little-endian). Two series hash equal iff
/// every field of every record is bit-for-bit identical — the cluster's
/// cross-replica and cluster-vs-in-process identity checks both pin
/// this.
pub fn series_hash(series: &Series) -> String {
    let mut bytes = Vec::with_capacity(series.records.len() * 64);
    for r in &series.records {
        bytes.extend_from_slice(&r.t.to_le_bytes());
        for v in [r.loss, r.test_error, r.opt_gap, r.consensus] {
            bytes.extend_from_slice(&v.to_bits().to_le_bytes());
        }
        bytes.extend_from_slice(&r.bits.to_le_bytes());
        bytes.extend_from_slice(&r.comm_rounds.to_le_bytes());
        bytes.extend_from_slice(&(r.fired as u64).to_le_bytes());
    }
    format!("{:016x}", crate::sweep::spec::fnv64(&bytes))
}

fn ckpt_path(dir: &Path, rank: usize) -> PathBuf {
    dir.join("ckpt").join(format!("node-{rank}.ckpt"))
}

fn ckpt_series_path(dir: &Path, rank: usize) -> PathBuf {
    dir.join("ckpt").join(format!("node-{rank}.series.jsonl"))
}

/// Where rank `rank` announces "kill me now" to the launcher.
pub fn kill_marker_path(dir: &Path, rank: usize) -> PathBuf {
    dir.join("kill").join(format!("node-{rank}.json"))
}

/// Where rank `rank` writes its end-of-run summary.
pub fn summary_path(dir: &Path, rank: usize) -> PathBuf {
    dir.join("out").join(format!("node-{rank}.json"))
}

/// The drive-loop observer gluing the engine to the cluster: membership
/// heartbeats, crash-boundary checkpoints, and the kill-marker park.
struct NodeObserver {
    rank: usize,
    dir: PathBuf,
    membership: Membership,
    /// This rank's own crash windows still to be served, ascending.
    windows: Vec<CrashWindow>,
    checkpoint_every: u64,
    verbose: bool,
}

impl NodeObserver {
    /// The pending own-crash window starting exactly at `t`, if any.
    fn window_at(&self, t: u64) -> Option<&CrashWindow> {
        self.windows.iter().find(|w| w.down == t)
    }
}

impl RunObserver for NodeObserver {
    fn tick(&mut self, t: u64) -> Result<bool, String> {
        if !self.membership.beat()? {
            // Someone else owns this rank now; abandoning (instead of
            // fighting over sockets) is the only safe move.
            return Ok(false);
        }
        if let Some(w) = self.window_at(t) {
            // The checkpoint at t = down was persisted at the end of
            // the previous iteration (see checkpoint_due); this process
            // now dies for real. Write the marker and wait for SIGKILL.
            let marker = Json::obj()
                .set("rank", self.rank)
                .set("pid", std::process::id() as u64)
                .set("t_down", w.down)
                .set("t_up", w.up);
            write_atomic(
                &kill_marker_path(&self.dir, self.rank),
                marker.to_string().as_bytes(),
            )?;
            if self.verbose {
                eprintln!(
                    "[node-{}] parked at t={} awaiting SIGKILL (rejoin at t={})",
                    self.rank, w.down, w.up
                );
            }
            let until = Instant::now() + PARK_CAP;
            while Instant::now() < until {
                std::thread::sleep(Duration::from_millis(50));
            }
            return Err(format!(
                "rank {}: no SIGKILL within {PARK_CAP:?} of the kill marker — launcher gone?",
                self.rank
            ));
        }
        Ok(true)
    }

    fn checkpoint_due(&mut self, t: u64) -> bool {
        // A crash boundary always checkpoints — the rejoin restores from
        // exactly t = down regardless of the cadence.
        (self.checkpoint_every > 0 && t % self.checkpoint_every == 0)
            || self.window_at(t).is_some()
    }

    fn persist(&mut self, ck: Checkpoint, series: &Series) -> Result<(), String> {
        let path = ckpt_path(&self.dir, self.rank);
        let tmp = path.with_extension("ckpt.tmp");
        ck.save(&tmp).map_err(|e| format!("{}: {e}", tmp.display()))?;
        std::fs::rename(&tmp, &path).map_err(|e| format!("{}: {e}", path.display()))?;
        let spath = ckpt_series_path(&self.dir, self.rank);
        let stmp = spath.with_extension("jsonl.tmp");
        series
            .write_jsonl(&stmp)
            .map_err(|e| format!("{}: {e}", stmp.display()))?;
        std::fs::rename(&stmp, &spath)
            .map_err(|e| format!("{}: {e}", spath.display()))
    }
}

/// Run one node process to completion: join, bind, drive, summarize.
/// This is the body of the hidden `cluster-node` subcommand.
pub fn run_node(opts: NodeOptions) -> Result<(), String> {
    let resolved = opts.cfg.resolve().map_err(|e| e.to_string())?;
    let n = opts.cfg.nodes;
    if opts.rank >= n {
        return Err(format!("rank {} out of range for {n} nodes", opts.rank));
    }
    let spec = opts.cfg.cluster.clone();
    let hash = config_hash(&opts.cfg);
    let connect = Duration::from_secs_f64(spec.connect_timeout_secs());
    for sub in ["ckpt", "kill", "out"] {
        let p = opts.dir.join(sub);
        std::fs::create_dir_all(&p).map_err(|e| format!("{}: {e}", p.display()))?;
    }

    let membership = Membership::join(
        &opts.dir,
        opts.rank,
        spec.lease_secs(),
        spec.heartbeat_secs(),
        connect,
    )?;
    let links = Links::bind(
        &opts.dir,
        opts.rank,
        n,
        spec.kind(),
        spec.host(),
        &hash,
        connect,
    )?;
    let stats: StatsHandle = links.stats_handle();

    let mut run = Run::from_resolved(&resolved, None, opts.cfg.workers.max(1));
    run.algo_mut()
        .set_transport(Box::new(SocketTransport::new(links, opts.mute_until)));

    // Rejoin: restore the checkpoint a previous incarnation persisted
    // at its crash boundary. The replay up to `mute_until` is silent
    // local recomputation (the node is down in every replica's plan).
    let cpath = ckpt_path(&opts.dir, opts.rank);
    if cpath.exists() {
        let ck = Checkpoint::load(&cpath).map_err(|e| format!("{}: {e}", cpath.display()))?;
        let spath = ckpt_series_path(&opts.dir, opts.rank);
        let label = run.series().label.clone();
        let series = Series::read_jsonl(&spath, label)
            .map_err(|e| format!("{}: {e}", spath.display()))?;
        let t0 = ck.t;
        run.restore(&ck, series).map_err(|e| e.to_string())?;
        if opts.verbose {
            eprintln!("[node-{}] restored checkpoint at t={t0}", opts.rank);
        }
    }

    let mut obs = NodeObserver {
        rank: opts.rank,
        dir: opts.dir.clone(),
        membership,
        windows: {
            let mut w: Vec<CrashWindow> = resolved
                .fault
                .crashes
                .iter()
                .filter(|w| w.node == opts.rank && w.down >= opts.min_crash_start)
                .cloned()
                .collect();
            w.sort_by_key(|w| w.down);
            w
        },
        checkpoint_every: opts.checkpoint_every,
        verbose: opts.verbose,
    };

    match run.drive(&mut obs)? {
        DriveEnd::Completed => {}
        DriveEnd::Stopped => {}
        DriveEnd::Abandoned => {
            return Err(format!(
                "rank {}: abandoned — membership lease lost",
                opts.rank
            ))
        }
    }

    // Summary: every rank writes one; the launcher cross-checks that
    // all replicas agree on the series fingerprint and bit totals.
    let (fired, checks) = run.fired_stats();
    let fault = run.snapshot().fault;
    let wire = stats.snapshot();
    let summary = Json::obj()
        .set("rank", opts.rank)
        .set("pid", std::process::id() as u64)
        .set("label", run.series().label.as_str())
        .set("t", run.t())
        .set("series_hash", series_hash(run.series()).as_str())
        .set("total_bits", run.bus().total_bits)
        .set("total_messages", run.bus().total_messages)
        .set("comm_rounds", run.bus().comm_rounds)
        .set("fired", fired)
        .set("checks", checks)
        .set("crashes", fault.crashes)
        .set("resyncs", fault.resyncs)
        .set("corrupt_discards", fault.corrupt_discards)
        .set("wire", wire.to_json());
    write_atomic(
        &summary_path(&opts.dir, opts.rank),
        summary.to_string().as_bytes(),
    )?;
    if opts.rank == 0 {
        let spath = opts.dir.join("out").join("series.jsonl");
        let tmp = spath.with_extension("jsonl.tmp");
        run.series()
            .write_jsonl(&tmp)
            .map_err(|e| format!("{}: {e}", tmp.display()))?;
        std::fs::rename(&tmp, &spath).map_err(|e| format!("{}: {e}", spath.display()))?;
    }
    obs.membership.leave()?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::RoundRecord;

    fn rec(t: u64, loss: f64) -> RoundRecord {
        RoundRecord {
            t,
            loss,
            test_error: 0.5,
            opt_gap: 0.25,
            bits: 100 + t,
            comm_rounds: t,
            consensus: 1e-3,
            fired: 3,
        }
    }

    #[test]
    fn series_hash_is_sensitive_to_every_bit() {
        let mut a = Series::new("x");
        a.push(rec(0, 1.0));
        a.push(rec(50, 0.5));
        let mut b = Series::new("y"); // label is not part of the hash
        b.push(rec(0, 1.0));
        b.push(rec(50, 0.5));
        assert_eq!(series_hash(&a), series_hash(&b));
        // One ULP of one field changes the fingerprint.
        b.records[1].loss = f64::from_bits(0.5f64.to_bits() + 1);
        assert_ne!(series_hash(&a), series_hash(&b));
        b.records[1].loss = 0.5;
        b.records[1].fired = 4;
        assert_ne!(series_hash(&a), series_hash(&b));
    }

    #[test]
    fn paths_are_per_rank() {
        let d = Path::new("/c");
        assert_eq!(ckpt_path(d, 2), Path::new("/c/ckpt/node-2.ckpt"));
        assert_eq!(kill_marker_path(d, 0), Path::new("/c/kill/node-0.json"));
        assert_eq!(summary_path(d, 7), Path::new("/c/out/node-7.json"));
    }
}
