//! The real multi-process decentralized runtime.
//!
//! `sparq cluster` turns the simulated decentralized run into N OS
//! processes exchanging real bytes, without forking any algorithm code:
//!
//! * Every node process runs the **complete** deterministic n-node
//!   engine (SPMD full replica). Seeded coins, triggers, stragglers,
//!   and fault windows replicate identically, so no control messages
//!   exist — the only bytes on the wire are each rank's own broadcasts.
//! * [`protocol`] — tagged payloads inside the `comm::wire` CRC frame:
//!   a config-pinned Hello handshake and `(t, from)`-headed data frames
//!   carrying `encode_sparse` bodies.
//! * [`socket`] — one stream per node pair (lower rank dials) over UDS
//!   or TCP, plus [`SocketTransport`] behind the engine's transport
//!   seam: sends are best-effort, receives are patient and fall back to
//!   the bit-identical local copy, and all degradation is counted.
//! * [`membership`] — join/failure detection on the heartbeat-lease
//!   claim store (`<dir>/membership/claims/node-R.claim`).
//! * [`node`] — the per-process drive loop: crash-boundary checkpoints,
//!   kill-marker park at own fault windows, end-of-run summary with an
//!   `f64::to_bits`-exact series fingerprint.
//! * [`launcher`] — spawn/supervise/`SIGKILL`/respawn, then cross-check
//!   that every replica (and optionally a fresh in-process run) agrees
//!   bit for bit.
//!
//! **The bit-identity contract.** In lockstep (all nodes live), a
//! cluster run's series, charged bit totals, and fired/checks counts
//! are `f64::to_bits`-identical to `Run::from_resolved` on the same
//! config: substitution of a received broadcast is a lossless f32-bit
//! round trip, and every other number is computed locally by the same
//! engine. Charged bits remain `Compressor::message_bits` — socket
//! framing (CRC armor + tag + round header) is accounted separately as
//! wire overhead in the summaries. With a fault plan, crash windows
//! become real `SIGKILL`s + checkpoint-restore rejoins, and the PR-6
//! resync charges still match the in-process engine exactly because
//! `fault_transition` is replicated computation.

pub mod launcher;
pub mod membership;
pub mod node;
pub mod protocol;
pub mod socket;

pub use launcher::{run_cluster, ClusterOptions, ClusterReport, KillEvent};
pub use node::{run_node, series_hash, NodeOptions};
pub use socket::{Links, SocketTransport, WireSnapshot};
