//! The cluster wire protocol: tagged payloads inside CRC frames.
//!
//! Every message on a node↔node socket is one `comm::wire::frame`
//! (`[len:u32 LE][crc32:u32 LE][payload]` — the same armor the serve
//! daemon and the chaos engine use). The payload's first byte is a tag:
//!
//! * [`TAG_HELLO`] — connection handshake, a JSON object
//!   `{"rank": R, "nodes": N, "config": HASH}`. Sent once by the dialer
//!   immediately after connecting; the acceptor rejects a peer whose
//!   node count or `config_hash` disagrees (two clusters sharing a
//!   directory, or a stale node from an earlier spec, must fail loudly
//!   instead of corrupting a run).
//! * [`TAG_DATA`] — one broadcast: `[t:u64 LE][from:u32 LE]` followed by
//!   the `comm::wire::encode_sparse` body. The `(t, from)` header lets a
//!   receiver discard frames from rounds it already resolved locally
//!   (e.g. a late TCP delivery after a recv timeout) instead of
//!   desynchronizing.
//!
//! The sparse body is the *charged* message — `Compressor::message_bits`
//! of exactly these coordinates. Tag + header + CRC armor are transport
//! overhead, tallied separately by [`super::socket::WireStats`].

use crate::util::json::Json;

/// Handshake payload tag (first frame on every connection).
pub const TAG_HELLO: u8 = 0x01;
/// Broadcast payload tag.
pub const TAG_DATA: u8 = 0x02;

/// Bytes the data header adds on top of the sparse body
/// (`tag + t + from`).
pub const DATA_HEADER_BYTES: usize = 1 + 8 + 4;

/// The handshake: who is dialing, and which experiment they think this
/// cluster is running.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Hello {
    pub rank: usize,
    pub nodes: usize,
    /// `sweep::spec::config_hash` of the cluster's config.
    pub config: String,
}

impl Hello {
    pub fn encode(&self) -> Vec<u8> {
        let j = Json::obj()
            .set("rank", self.rank)
            .set("nodes", self.nodes)
            .set("config", self.config.as_str());
        let mut out = vec![TAG_HELLO];
        out.extend_from_slice(j.to_string().as_bytes());
        out
    }
}

/// One decoded broadcast frame (body still `encode_sparse` bytes — the
/// receiver decodes it against its model dimension).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct DataMsg {
    pub t: u64,
    pub from: usize,
    pub body: Vec<u8>,
}

/// Encode a broadcast payload (framing happens at the socket layer).
pub fn encode_data(t: u64, from: usize, body: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(DATA_HEADER_BYTES + body.len());
    out.push(TAG_DATA);
    out.extend_from_slice(&t.to_le_bytes());
    out.extend_from_slice(&(from as u32).to_le_bytes());
    out.extend_from_slice(body);
    out
}

/// A decoded cluster payload.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ClusterMsg {
    Hello(Hello),
    Data(DataMsg),
}

/// Decode a checksum-verified payload. Every failure is a `String`
/// reason — the socket layer treats a malformed payload like a corrupt
/// frame (the connection is suspect) rather than panicking.
pub fn decode(payload: &[u8]) -> Result<ClusterMsg, String> {
    match payload.first() {
        Some(&TAG_HELLO) => {
            let text = std::str::from_utf8(&payload[1..])
                .map_err(|e| format!("hello is not UTF-8: {e}"))?;
            let j = Json::parse(text).map_err(|e| format!("hello is not JSON: {e}"))?;
            let field = |k: &str| {
                j.get(k)
                    .and_then(Json::as_usize)
                    .ok_or_else(|| format!("hello missing {k:?}"))
            };
            Ok(ClusterMsg::Hello(Hello {
                rank: field("rank")?,
                nodes: field("nodes")?,
                config: j
                    .get("config")
                    .and_then(Json::as_str)
                    .ok_or("hello missing \"config\"")?
                    .to_string(),
            }))
        }
        Some(&TAG_DATA) => {
            if payload.len() < DATA_HEADER_BYTES {
                return Err(format!(
                    "data frame is {} bytes; header alone needs {DATA_HEADER_BYTES}",
                    payload.len()
                ));
            }
            let t = u64::from_le_bytes(payload[1..9].try_into().expect("8 bytes"));
            let from = u32::from_le_bytes(payload[9..13].try_into().expect("4 bytes")) as usize;
            Ok(ClusterMsg::Data(DataMsg {
                t,
                from,
                body: payload[DATA_HEADER_BYTES..].to_vec(),
            }))
        }
        Some(tag) => Err(format!("unknown payload tag {tag:#04x}")),
        None => Err("empty payload".into()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::wire::{decode_sparse, encode_sparse};
    use crate::compress::SparseVec;

    #[test]
    fn hello_round_trips() {
        let h = Hello {
            rank: 3,
            nodes: 8,
            config: "0123456789abcdef".into(),
        };
        match decode(&h.encode()).unwrap() {
            ClusterMsg::Hello(back) => assert_eq!(back, h),
            other => panic!("expected Hello, got {other:?}"),
        }
    }

    #[test]
    fn data_round_trips_with_the_sparse_body_intact() {
        let d = 100;
        let mut q = SparseVec::new();
        q.push(3, 1.5);
        q.push(97, -0.25);
        let body = encode_sparse(&q, d);
        let payload = encode_data(12345, 2, &body);
        assert_eq!(payload.len(), DATA_HEADER_BYTES + body.len());
        match decode(&payload).unwrap() {
            ClusterMsg::Data(msg) => {
                assert_eq!(msg.t, 12345);
                assert_eq!(msg.from, 2);
                // the body decodes to the exact message — the
                // substitution contract's lossless round trip
                assert_eq!(decode_sparse(&msg.body, d).unwrap(), q);
            }
            other => panic!("expected Data, got {other:?}"),
        }
    }

    #[test]
    fn malformed_payloads_are_errors_not_panics() {
        assert!(decode(&[]).is_err());
        assert!(decode(&[0x7f, 1, 2]).is_err());
        assert!(decode(&[TAG_DATA, 1, 2]).is_err()); // truncated header
        assert!(decode(&[TAG_HELLO, 0xff]).is_err()); // not UTF-8
        let mut bad = Hello {
            rank: 0,
            nodes: 2,
            config: "x".into(),
        }
        .encode();
        bad.truncate(bad.len() - 2); // torn JSON
        assert!(decode(&bad).is_err());
        // hello without a config hash is rejected
        let mut j = vec![TAG_HELLO];
        j.extend_from_slice(br#"{"rank": 0, "nodes": 2}"#);
        assert!(decode(&j).is_err());
    }

    #[test]
    fn empty_broadcasts_encode() {
        let q = SparseVec::new();
        let body = encode_sparse(&q, 16);
        let payload = encode_data(0, 0, &body);
        match decode(&payload).unwrap() {
            ClusterMsg::Data(msg) => {
                assert_eq!(decode_sparse(&msg.body, 16).unwrap(), q)
            }
            other => panic!("expected Data, got {other:?}"),
        }
    }
}
