//! The `sparq cluster` launcher: spawn N node processes, supervise
//! them, deliver real `SIGKILL`s for fault-plan crash windows, and
//! cross-check that every replica tells the same story.
//!
//! The launcher never touches algorithm state. It owns exactly four
//! jobs: (1) write `<dir>/config.json` and spawn one `cluster-node`
//! child per rank with stdout/stderr teed to `<dir>/log/`; (2) wait
//! for the membership claims to confirm the cluster formed; (3) watch
//! `<dir>/kill/` for markers — a node parks at its own crash boundary
//! and asks to die — then `SIGKILL` the process, delete its membership
//! claim, and respawn it with `--mute-until <up>` so the restored
//! checkpoint replays silently and rejoins at `t = up`; (4) collect
//! the per-rank summaries and refuse to report success unless every
//! replica's series fingerprint, bit totals, and trigger counts agree
//! (with `verify`, also against a fresh in-process run).

use std::collections::HashSet;
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

use super::membership;
use super::node::{series_hash, summary_path};
use super::socket::write_atomic;
use crate::config::{Algo, ExperimentConfig};
use crate::run::Run;
use crate::util::json::Json;

const POLL: Duration = Duration::from_millis(50);

/// Launcher inputs (the `sparq cluster` flag surface).
pub struct ClusterOptions {
    pub cfg: ExperimentConfig,
    /// The shared cluster directory (sockets, checkpoints, membership,
    /// logs, summaries all live here).
    pub dir: PathBuf,
    /// The `sparq` binary to spawn nodes from (normally
    /// `std::env::current_exe()`).
    pub exe: PathBuf,
    /// Checkpoint cadence forwarded to every node (0 = crash
    /// boundaries only).
    pub checkpoint_every: u64,
    /// Also run the config in-process and demand bit-identity.
    pub verify: bool,
    pub verbose: bool,
    /// Watchdog: kill everything and fail if the cluster has not
    /// finished within this budget (0 = no watchdog).
    pub timeout_secs: f64,
}

/// One delivered crash: the launcher really `SIGKILL`ed rank `rank` at
/// iteration boundary `t_down` and respawned it to rejoin at `t_up`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct KillEvent {
    pub rank: usize,
    pub t_down: u64,
    pub t_up: u64,
}

/// What one rank reported at the end of its run.
#[derive(Clone, Debug)]
pub struct NodeSummary {
    pub rank: usize,
    pub series_hash: String,
    pub total_bits: u64,
    pub total_messages: u64,
    pub comm_rounds: u64,
    pub fired: u64,
    pub checks: u64,
    pub crashes: u64,
    pub resyncs: u64,
    pub wire_fallbacks: u64,
    pub wire_mismatches: u64,
}

/// The cross-checked outcome of one cluster run.
#[derive(Clone, Debug)]
pub struct ClusterReport {
    pub nodes: usize,
    /// The (agreed) series fingerprint.
    pub series_hash: String,
    pub total_bits: u64,
    pub fired: u64,
    pub checks: u64,
    pub crashes: u64,
    pub resyncs: u64,
    pub kills: Vec<KillEvent>,
    /// Summed over ranks — nonzero fallbacks mean some receives
    /// degraded to local computation (completeness, not correctness).
    pub wire_fallbacks: u64,
    pub wire_mismatches: u64,
    /// `Some(hash)` when `verify` ran the config in-process and the
    /// fingerprints matched (a mismatch is an `Err`, not a report).
    pub verified: Option<String>,
}

impl ClusterReport {
    pub fn to_json(&self) -> Json {
        let kills = self
            .kills
            .iter()
            .map(|k| {
                Json::obj()
                    .set("rank", k.rank)
                    .set("t_down", k.t_down)
                    .set("t_up", k.t_up)
            })
            .collect::<Vec<_>>();
        let j = Json::obj()
            .set("nodes", self.nodes)
            .set("series_hash", self.series_hash.as_str())
            .set("total_bits", self.total_bits)
            .set("fired", self.fired)
            .set("checks", self.checks)
            .set("crashes", self.crashes)
            .set("resyncs", self.resyncs)
            .set("kills", Json::Arr(kills))
            .set("wire_fallbacks", self.wire_fallbacks)
            .set("wire_mismatches", self.wire_mismatches);
        match &self.verified {
            Some(h) => j.set("verified", h.as_str()),
            None => j,
        }
    }
}

/// Launch, supervise, and cross-check one cluster run.
pub fn run_cluster(opts: &ClusterOptions) -> Result<ClusterReport, String> {
    let cfg = &opts.cfg;
    let n = cfg.nodes;
    if n < 2 {
        return Err(format!("a cluster needs at least 2 nodes, got {n}"));
    }
    if cfg.algo == Algo::Vanilla {
        // ExactAveraging has no compressed-broadcast phase, so there is
        // nothing for the socket transport to carry.
        return Err("algo 'vanilla' has no broadcast phase to distribute; \
                    use sparq or choco"
            .into());
    }
    cfg.resolve().map_err(|e| e.to_string())?;

    for sub in ["sock", "kill", "out", "ckpt", "log"] {
        let p = opts.dir.join(sub);
        std::fs::create_dir_all(&p).map_err(|e| format!("{}: {e}", p.display()))?;
    }
    let claims = opts.dir.join("membership").join("claims");
    std::fs::create_dir_all(&claims).map_err(|e| format!("{}: {e}", claims.display()))?;
    write_atomic(
        &opts.dir.join("config.json"),
        cfg.to_json().to_string().as_bytes(),
    )?;

    let connect = Duration::from_secs_f64(cfg.cluster.connect_timeout_secs());
    let mut children: Vec<Option<Child>> = Vec::with_capacity(n);
    for rank in 0..n {
        children.push(Some(spawn_node(opts, rank, 0)?));
    }
    // Join detection: the cluster has formed when every rank holds its
    // membership claim.
    if let Err(e) = membership::wait_for_cluster(&opts.dir, n, connect) {
        kill_all(&mut children);
        return Err(e);
    }
    if opts.verbose {
        eprintln!("[cluster] {n} nodes joined");
    }

    let mut kills: Vec<KillEvent> = Vec::new();
    let mut done: HashSet<usize> = HashSet::new();
    let started = Instant::now();
    loop {
        // 1. Kill markers: a node parked at its crash boundary.
        for rank in 0..n {
            let marker = super::node::kill_marker_path(&opts.dir, rank);
            if !marker.exists() {
                continue;
            }
            let t_up = match read_marker(&marker) {
                Some((t_down, t_up)) => {
                    kills.push(KillEvent { rank, t_down, t_up });
                    t_up
                }
                None => continue, // torn write; next poll sees it whole
            };
            if let Some(mut child) = children[rank].take() {
                let _ = child.kill(); // SIGKILL — no chance to clean up
                let _ = child.wait();
            }
            std::fs::remove_file(&marker).map_err(|e| format!("{}: {e}", marker.display()))?;
            // Free the rank immediately instead of waiting out the
            // lease, then respawn into the rejoin path.
            let claim = membership::claim_file(&opts.dir, rank);
            if claim.exists() {
                std::fs::remove_file(&claim).map_err(|e| format!("{}: {e}", claim.display()))?;
            }
            if opts.verbose {
                eprintln!("[cluster] killed node-{rank}, respawning for t={t_up}");
            }
            children[rank] = Some(spawn_node(opts, rank, t_up)?);
        }

        // 2. Child exits: success marks the rank done; failure sinks
        //    the whole cluster (one diverged replica is not a result).
        for rank in 0..n {
            let Some(child) = children[rank].as_mut() else {
                continue;
            };
            match child.try_wait() {
                Ok(Some(status)) if status.success() => {
                    children[rank] = None;
                    done.insert(rank);
                }
                Ok(Some(status)) => {
                    kill_all(&mut children);
                    return Err(format!(
                        "node-{rank} exited with {status}; see {}",
                        log_path(&opts.dir, rank).display()
                    ));
                }
                Ok(None) => {}
                Err(e) => {
                    kill_all(&mut children);
                    return Err(format!("node-{rank}: wait: {e}"));
                }
            }
        }
        if done.len() == n {
            break;
        }
        if opts.timeout_secs > 0.0 && started.elapsed().as_secs_f64() > opts.timeout_secs {
            kill_all(&mut children);
            return Err(format!(
                "cluster timed out after {:.0}s with {}/{n} nodes finished",
                opts.timeout_secs,
                done.len()
            ));
        }
        std::thread::sleep(POLL);
    }

    // 3. Cross-check: every replica must have computed the same run.
    let summaries: Vec<NodeSummary> = (0..n)
        .map(|rank| read_summary(&opts.dir, rank))
        .collect::<Result<_, _>>()?;
    let first = &summaries[0];
    for s in &summaries[1..] {
        if s.series_hash != first.series_hash
            || s.total_bits != first.total_bits
            || s.fired != first.fired
            || s.checks != first.checks
        {
            return Err(format!(
                "replica divergence: node-0 {{hash {}, bits {}, fired {}/{}}} vs node-{} \
                 {{hash {}, bits {}, fired {}/{}}}",
                first.series_hash,
                first.total_bits,
                first.fired,
                first.checks,
                s.rank,
                s.series_hash,
                s.total_bits,
                s.fired,
                s.checks
            ));
        }
    }

    // 4. Optional in-process verification: same config, no sockets.
    let verified = if opts.verify {
        let resolved = cfg.resolve().map_err(|e| e.to_string())?;
        let mut run = Run::from_resolved(&resolved, None, cfg.workers.max(1));
        run.run_to_end()?;
        let h = series_hash(run.series());
        let (fired, checks) = run.fired_stats();
        if h != first.series_hash
            || run.bus().total_bits != first.total_bits
            || fired != first.fired
            || checks != first.checks
        {
            return Err(format!(
                "cluster diverged from the in-process engine: cluster {{hash {}, bits {}, \
                 fired {}/{}}} vs in-process {{hash {h}, bits {}, fired {fired}/{checks}}}",
                first.series_hash,
                first.total_bits,
                first.fired,
                first.checks,
                run.bus().total_bits
            ));
        }
        Some(h)
    } else {
        None
    };

    let report = ClusterReport {
        nodes: n,
        series_hash: first.series_hash.clone(),
        total_bits: first.total_bits,
        fired: first.fired,
        checks: first.checks,
        crashes: first.crashes,
        resyncs: first.resyncs,
        kills,
        wire_fallbacks: summaries.iter().map(|s| s.wire_fallbacks).sum(),
        wire_mismatches: summaries.iter().map(|s| s.wire_mismatches).sum(),
        verified,
    };
    write_atomic(
        &opts.dir.join("report.json"),
        report.to_json().to_string().as_bytes(),
    )?;
    Ok(report)
}

fn log_path(dir: &Path, rank: usize) -> PathBuf {
    dir.join("log").join(format!("node-{rank}.log"))
}

/// Spawn one `cluster-node` child. `mute_until > 0` selects the rejoin
/// path: restore the crash-boundary checkpoint, replay silently, and
/// skip crash windows already served.
fn spawn_node(opts: &ClusterOptions, rank: usize, mute_until: u64) -> Result<Child, String> {
    let log = std::fs::File::create(log_path(&opts.dir, rank))
        .map_err(|e| format!("{}: {e}", log_path(&opts.dir, rank).display()))?;
    let err = log
        .try_clone()
        .map_err(|e| format!("clone log handle: {e}"))?;
    let mut cmd = Command::new(&opts.exe);
    cmd.arg("cluster-node")
        .arg("--dir")
        .arg(&opts.dir)
        .arg("--rank")
        .arg(rank.to_string())
        .arg("--checkpoint-every")
        .arg(opts.checkpoint_every.to_string())
        .stdin(Stdio::null())
        .stdout(Stdio::from(log))
        .stderr(Stdio::from(err));
    if mute_until > 0 {
        cmd.arg("--mute-until")
            .arg(mute_until.to_string())
            .arg("--min-crash-start")
            .arg(mute_until.to_string());
    }
    if opts.verbose {
        cmd.arg("--verbose");
    }
    cmd.spawn()
        .map_err(|e| format!("spawn {} cluster-node: {e}", opts.exe.display()))
}

fn kill_all(children: &mut [Option<Child>]) {
    for c in children.iter_mut() {
        if let Some(mut child) = c.take() {
            let _ = child.kill();
            let _ = child.wait();
        }
    }
}

fn read_marker(path: &Path) -> Option<(u64, u64)> {
    let text = std::fs::read_to_string(path).ok()?;
    let j = Json::parse(&text).ok()?;
    Some((
        j.get("t_down").and_then(Json::as_u64)?,
        j.get("t_up").and_then(Json::as_u64)?,
    ))
}

fn read_summary(dir: &Path, rank: usize) -> Result<NodeSummary, String> {
    let path = summary_path(dir, rank);
    let text = std::fs::read_to_string(&path).map_err(|e| format!("{}: {e}", path.display()))?;
    let j = Json::parse(&text).map_err(|e| format!("{}: {e}", path.display()))?;
    let num = |key: &str| j.get(key).and_then(Json::as_u64).unwrap_or(0);
    let wire = |key: &str| {
        j.get("wire")
            .and_then(|w| w.get(key))
            .and_then(Json::as_u64)
            .unwrap_or(0)
    };
    Ok(NodeSummary {
        rank,
        series_hash: j
            .get("series_hash")
            .and_then(Json::as_str)
            .ok_or_else(|| format!("{}: missing series_hash", path.display()))?
            .to_string(),
        total_bits: num("total_bits"),
        total_messages: num("total_messages"),
        comm_rounds: num("comm_rounds"),
        fired: num("fired"),
        checks: num("checks"),
        crashes: num("crashes"),
        resyncs: num("resyncs"),
        wire_fallbacks: wire("fallbacks"),
        wire_mismatches: wire("mismatches"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vanilla_and_single_node_clusters_are_rejected() {
        let base = ExperimentConfig {
            nodes: 4,
            ..Default::default()
        };
        let opts = |cfg: ExperimentConfig| ClusterOptions {
            cfg,
            dir: std::env::temp_dir().join("sparq-launcher-reject"),
            exe: PathBuf::from("/nonexistent"),
            checkpoint_every: 0,
            verify: false,
            verbose: false,
            timeout_secs: 1.0,
        };
        let mut vanilla = base.clone();
        vanilla.algo = Algo::Vanilla;
        let err = run_cluster(&opts(vanilla)).unwrap_err();
        assert!(err.contains("vanilla"), "{err}");
        let mut single = base;
        single.nodes = 1;
        let err = run_cluster(&opts(single)).unwrap_err();
        assert!(err.contains("at least 2"), "{err}");
    }

    #[test]
    fn report_json_carries_the_identity_fields() {
        let r = ClusterReport {
            nodes: 4,
            series_hash: "ab".into(),
            total_bits: 10,
            fired: 3,
            checks: 9,
            crashes: 1,
            resyncs: 2,
            kills: vec![KillEvent {
                rank: 2,
                t_down: 40,
                t_up: 60,
            }],
            wire_fallbacks: 0,
            wire_mismatches: 0,
            verified: Some("ab".into()),
        };
        let j = r.to_json();
        assert_eq!(j.get("series_hash").and_then(Json::as_str), Some("ab"));
        assert_eq!(j.get("verified").and_then(Json::as_str), Some("ab"));
        let kills = match j.get("kills") {
            Some(Json::Arr(v)) => v,
            other => panic!("kills should be an array, got {other:?}"),
        };
        assert_eq!(kills[0].get("t_down").and_then(Json::as_u64), Some(40));
    }
}
