//! Sockets between node processes: listeners, dialing, and the
//! [`SocketTransport`] that plugs into the engine's transport seam.
//!
//! Every node process is a full replica of the deterministic n-node
//! engine, so the only bytes that must travel are each rank's own
//! broadcasts. The link layer keeps one stream per peer in a registry;
//! for the pair `(a, b)` with `a < b`, **the lower rank dials** the
//! higher rank's listener (one stream per pair, no simultaneous-connect
//! races). Endpoints live under `<dir>/sock/`: rank r listens on
//! `node-r.sock` (UDS) or on an ephemeral TCP port advertised in
//! `node-r.addr`.
//!
//! Receives are *patient but not fatal*: a missing peer or a silent
//! stream falls back — after `connect_timeout` — to the locally
//! computed copy of the message, which is bit-identical to what the
//! wire would have carried (the substitution contract in
//! [`crate::comm::transport`]). Fallbacks and substitution mismatches
//! are tallied in [`WireStats`] so a run that degraded to local
//! computation is visible in the summary instead of silently passing.

use std::collections::HashMap;
use std::io;
use std::net::TcpListener;
#[cfg(unix)]
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use super::protocol::{decode, encode_data, ClusterMsg, Hello};
use crate::comm::transport::Transport;
use crate::comm::wire::{decode_sparse, encode_sparse, FRAME_OVERHEAD};
use crate::compress::SparseVec;
use crate::config::SocketKind;
use crate::serve::protocol::{read_frame, write_frame, FrameIn, Stream};
use crate::util::json::Json;

/// How long the accept loop sleeps between polls, and the granularity
/// at which blocked reads re-check their deadline.
const POLL: Duration = Duration::from_millis(25);

/// Transport-layer counters (diagnostic — never part of the charged
/// bit accounting).
#[derive(Default)]
pub struct WireStats {
    frames_sent: AtomicU64,
    frames_received: AtomicU64,
    bytes_sent: AtomicU64,
    bytes_received: AtomicU64,
    /// Receives that timed out / failed and used the local copy.
    fallbacks: AtomicU64,
    /// Received messages that differed from the local computation
    /// (replica divergence — should stay 0).
    mismatches: AtomicU64,
    stale_drops: AtomicU64,
    reconnects: AtomicU64,
}

/// A point-in-time copy of [`WireStats`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct WireSnapshot {
    pub frames_sent: u64,
    pub frames_received: u64,
    pub bytes_sent: u64,
    pub bytes_received: u64,
    pub fallbacks: u64,
    pub mismatches: u64,
    pub stale_drops: u64,
    pub reconnects: u64,
}

impl WireSnapshot {
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("frames_sent", self.frames_sent)
            .set("frames_received", self.frames_received)
            .set("bytes_sent", self.bytes_sent)
            .set("bytes_received", self.bytes_received)
            .set("fallbacks", self.fallbacks)
            .set("mismatches", self.mismatches)
            .set("stale_drops", self.stale_drops)
            .set("reconnects", self.reconnects)
    }
}

impl WireStats {
    fn snapshot(&self) -> WireSnapshot {
        let get = |a: &AtomicU64| a.load(Ordering::Relaxed);
        WireSnapshot {
            frames_sent: get(&self.frames_sent),
            frames_received: get(&self.frames_received),
            bytes_sent: get(&self.bytes_sent),
            bytes_received: get(&self.bytes_received),
            fallbacks: get(&self.fallbacks),
            mismatches: get(&self.mismatches),
            stale_drops: get(&self.stale_drops),
            reconnects: get(&self.reconnects),
        }
    }
}

fn bump(a: &AtomicU64) {
    a.fetch_add(1, Ordering::Relaxed);
}

/// Cloneable read access to a link layer's [`WireStats`].
#[derive(Clone)]
pub struct StatsHandle(Arc<Shared>);

impl StatsHandle {
    pub fn snapshot(&self) -> WireSnapshot {
        self.0.stats.snapshot()
    }
}

/// State shared between the engine thread and the accept thread.
struct Shared {
    /// Live streams by peer rank. The engine thread *removes* a stream
    /// for I/O and puts it back afterwards; the accept thread inserts
    /// (replacing — a fresh dial from a rejoined peer is authoritative).
    streams: Mutex<HashMap<usize, Stream>>,
    cv: Condvar,
    stop: AtomicBool,
    stats: WireStats,
}

enum Listener {
    Tcp(TcpListener),
    #[cfg(unix)]
    Unix(UnixListener),
}

impl Listener {
    fn accept(&self) -> io::Result<Stream> {
        match self {
            Listener::Tcp(l) => l.accept().map(|(s, _)| Stream::Tcp(s)),
            #[cfg(unix)]
            Listener::Unix(l) => l.accept().map(|(s, _)| Stream::Unix(s)),
        }
    }
}

/// The per-process link layer: one listener plus one stream per peer.
pub struct Links {
    rank: usize,
    n: usize,
    sock_dir: PathBuf,
    kind: SocketKind,
    hello: Vec<u8>,
    connect_timeout: Duration,
    shared: Arc<Shared>,
    accept: Option<thread::JoinHandle<()>>,
    /// Files to unlink on drop (UDS socket / TCP addr advertisement).
    cleanup: Vec<PathBuf>,
}

impl Links {
    /// Bind rank `rank`'s listener under `<dir>/sock/` and start the
    /// accept thread. `config` is the cluster's `config_hash`, pinned in
    /// every handshake.
    pub fn bind(
        dir: &Path,
        rank: usize,
        n: usize,
        kind: SocketKind,
        host: &str,
        config: &str,
        connect_timeout: Duration,
    ) -> Result<Links, String> {
        if rank >= n || n < 2 {
            return Err(format!("rank {rank} out of range for {n} nodes"));
        }
        let sock_dir = dir.join("sock");
        std::fs::create_dir_all(&sock_dir)
            .map_err(|e| format!("{}: {e}", sock_dir.display()))?;
        let mut cleanup = Vec::new();
        let listener = match kind {
            SocketKind::Uds => {
                #[cfg(unix)]
                {
                    let path = sock_path(&sock_dir, rank);
                    if path.exists() {
                        // A live socket here means another process owns
                        // this rank; a dead one is debris from a crash.
                        if UnixStream::connect(&path).is_ok() {
                            return Err(format!("{}: endpoint busy", path.display()));
                        }
                        std::fs::remove_file(&path)
                            .map_err(|e| format!("{}: {e}", path.display()))?;
                    }
                    let l = UnixListener::bind(&path)
                        .map_err(|e| format!("{}: {e}", path.display()))?;
                    cleanup.push(path);
                    Listener::Unix(l)
                }
                #[cfg(not(unix))]
                {
                    return Err("uds cluster transport needs a unix platform".into());
                }
            }
            SocketKind::Tcp => {
                let l = TcpListener::bind((host, 0))
                    .map_err(|e| format!("bind {host}:0: {e}"))?;
                let addr = l.local_addr().map_err(|e| e.to_string())?;
                let path = addr_path(&sock_dir, rank);
                write_atomic(&path, addr.to_string().as_bytes())?;
                cleanup.push(path);
                Listener::Tcp(l)
            }
        };
        let hello = Hello {
            rank,
            nodes: n,
            config: config.to_string(),
        }
        .encode();
        let shared = Arc::new(Shared {
            streams: Mutex::new(HashMap::new()),
            cv: Condvar::new(),
            stop: AtomicBool::new(false),
            stats: WireStats::default(),
        });
        let accept = spawn_accept(listener, rank, n, config.to_string(), Arc::clone(&shared))?;
        Ok(Links {
            rank,
            n,
            sock_dir,
            kind,
            hello,
            connect_timeout,
            shared,
            accept: Some(accept),
            cleanup,
        })
    }

    pub fn rank(&self) -> usize {
        self.rank
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn kind(&self) -> SocketKind {
        self.kind
    }

    pub fn stats(&self) -> WireSnapshot {
        self.shared.stats.snapshot()
    }

    /// A read handle onto the counters that outlives handing the links
    /// to a [`SocketTransport`] (the node keeps one for its summary).
    pub fn stats_handle(&self) -> StatsHandle {
        StatsHandle(Arc::clone(&self.shared))
    }

    /// For the pair `(self.rank, peer)`, is this process the dialer?
    fn is_dialer(&self, peer: usize) -> bool {
        self.rank < peer
    }

    /// Send one already-encoded payload to `peer`, best-effort: on a
    /// dead stream the dialer side redials and the acceptor side waits
    /// for a fresh dial, up to `connect_timeout`. Returns whether the
    /// frame went out.
    pub fn send_to(&self, peer: usize, payload: &[u8]) -> bool {
        let deadline = Instant::now() + self.connect_timeout;
        loop {
            let Some(mut s) = self.take_stream(peer, deadline) else {
                bump(&self.shared.stats.fallbacks);
                return false;
            };
            match write_frame(&mut s, payload) {
                Ok(()) => {
                    bump(&self.shared.stats.frames_sent);
                    self.shared
                        .stats
                        .bytes_sent
                        .fetch_add((payload.len() + FRAME_OVERHEAD) as u64, Ordering::Relaxed);
                    self.put_back(peer, s);
                    return true;
                }
                Err(_) => {
                    // Stream is dead (peer killed / rejoining): drop it
                    // and let the loop re-establish or time out.
                    bump(&self.shared.stats.reconnects);
                    drop(s);
                    if Instant::now() >= deadline {
                        bump(&self.shared.stats.fallbacks);
                        return false;
                    }
                }
            }
        }
    }

    /// Receive sender `from`'s broadcast for round `t`. Returns the
    /// sparse body bytes, or `None` after patience runs out (the caller
    /// falls back to its local copy). Frames for earlier rounds are
    /// stale deliveries (e.g. TCP buffering across a rejoin) and are
    /// dropped; a frame from the *future* means this replica desynced,
    /// which the fallback path also absorbs.
    pub fn recv_data(&self, from: usize, t: u64) -> Option<Vec<u8>> {
        let deadline = Instant::now() + self.connect_timeout;
        loop {
            let Some(mut s) = self.take_stream(from, deadline) else {
                bump(&self.shared.stats.fallbacks);
                return None;
            };
            let _ = s.set_read_timeout(Some(POLL));
            let stop = || {
                self.shared.stop.load(Ordering::Relaxed) || Instant::now() >= deadline
            };
            loop {
                match read_frame(&mut s, &stop) {
                    Ok(FrameIn::Msg(payload)) => match decode(&payload) {
                        Ok(ClusterMsg::Data(msg)) if msg.from == from && msg.t == t => {
                            bump(&self.shared.stats.frames_received);
                            self.shared.stats.bytes_received.fetch_add(
                                (payload.len() + FRAME_OVERHEAD) as u64,
                                Ordering::Relaxed,
                            );
                            self.put_back(from, s);
                            return Some(msg.body);
                        }
                        Ok(ClusterMsg::Data(msg)) if msg.t < t => {
                            bump(&self.shared.stats.stale_drops);
                        }
                        Ok(ClusterMsg::Data(_)) => {
                            // A future round: we cannot un-read it, so
                            // surrender this round to the local copy.
                            bump(&self.shared.stats.mismatches);
                            bump(&self.shared.stats.fallbacks);
                            self.put_back(from, s);
                            return None;
                        }
                        // A re-handshake on a replaced stream; harmless.
                        Ok(ClusterMsg::Hello(_)) => {}
                        Err(_) => bump(&self.shared.stats.stale_drops),
                    },
                    Ok(FrameIn::Corrupt { fatal: false, .. }) => {}
                    Ok(FrameIn::Corrupt { fatal: true, .. }) | Ok(FrameIn::Eof) | Err(_) => {
                        bump(&self.shared.stats.reconnects);
                        drop(s);
                        break; // outer loop redials / waits for re-accept
                    }
                    Ok(FrameIn::Stopped) => {
                        bump(&self.shared.stats.fallbacks);
                        self.put_back(from, s);
                        return None;
                    }
                }
            }
            if Instant::now() >= deadline {
                bump(&self.shared.stats.fallbacks);
                return None;
            }
        }
    }

    /// Remove `peer`'s stream from the registry for exclusive I/O,
    /// establishing it first if needed: dial (lower rank) or wait for
    /// the peer's dial (higher rank).
    fn take_stream(&self, peer: usize, deadline: Instant) -> Option<Stream> {
        let mut map = self.shared.streams.lock().expect("streams lock");
        loop {
            if let Some(s) = map.remove(&peer) {
                return Some(s);
            }
            if self.shared.stop.load(Ordering::Relaxed) {
                return None;
            }
            if self.is_dialer(peer) {
                drop(map);
                return self.dial(peer, deadline);
            }
            if Instant::now() >= deadline {
                return None;
            }
            let (m, _) = self
                .shared
                .cv
                .wait_timeout(map, POLL)
                .expect("streams lock");
            map = m;
        }
    }

    /// Re-register a stream after I/O. If the accept thread installed a
    /// fresh stream meanwhile (peer rejoined), the fresh one wins.
    fn put_back(&self, peer: usize, s: Stream) {
        let mut map = self.shared.streams.lock().expect("streams lock");
        map.entry(peer).or_insert(s);
        self.shared.cv.notify_all();
    }

    /// Connect to `peer`'s listener and shake hands, retrying until
    /// `deadline` (the peer may still be binding, or mid-rejoin).
    fn dial(&self, peer: usize, deadline: Instant) -> Option<Stream> {
        loop {
            if self.shared.stop.load(Ordering::Relaxed) || Instant::now() >= deadline {
                return None;
            }
            if let Some(endpoint) = self.endpoint_of(peer) {
                if let Ok(mut s) = Stream::connect(&endpoint) {
                    if write_frame(&mut s, &self.hello).is_ok() {
                        return Some(s);
                    }
                }
            }
            thread::sleep(POLL);
        }
    }

    /// The `--socket`-style operand for `peer`'s listener.
    fn endpoint_of(&self, peer: usize) -> Option<String> {
        match self.kind {
            SocketKind::Uds => Some(sock_path(&self.sock_dir, peer).display().to_string()),
            SocketKind::Tcp => std::fs::read_to_string(addr_path(&self.sock_dir, peer))
                .ok()
                .map(|s| s.trim().to_string())
                .filter(|s| !s.is_empty()),
        }
    }

    /// Stop the accept thread and close everything.
    pub fn shutdown(&mut self) {
        self.shared.stop.store(true, Ordering::Relaxed);
        self.shared.cv.notify_all();
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        self.shared.streams.lock().expect("streams lock").clear();
        for p in self.cleanup.drain(..) {
            let _ = std::fs::remove_file(p);
        }
    }
}

impl Drop for Links {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn sock_path(sock_dir: &Path, rank: usize) -> PathBuf {
    sock_dir.join(format!("node-{rank}.sock"))
}

fn addr_path(sock_dir: &Path, rank: usize) -> PathBuf {
    sock_dir.join(format!("node-{rank}.addr"))
}

/// Write via tmp + rename so readers never see a torn file.
pub(crate) fn write_atomic(path: &Path, bytes: &[u8]) -> Result<(), String> {
    let tmp = path.with_extension("tmp");
    std::fs::write(&tmp, bytes).map_err(|e| format!("{}: {e}", tmp.display()))?;
    std::fs::rename(&tmp, path).map_err(|e| format!("{}: {e}", path.display()))
}

/// Accept loop: validate each dialer's handshake against this cluster's
/// shape before admitting the stream. A peer from a different config or
/// node count is refused (dropped) — it will keep redialing and failing
/// loudly rather than corrupting the run.
fn spawn_accept(
    listener: Listener,
    rank: usize,
    n: usize,
    config: String,
    shared: Arc<Shared>,
) -> Result<thread::JoinHandle<()>, String> {
    match &listener {
        Listener::Tcp(l) => l.set_nonblocking(true).map_err(|e| e.to_string())?,
        #[cfg(unix)]
        Listener::Unix(l) => l.set_nonblocking(true).map_err(|e| e.to_string())?,
    }
    thread::Builder::new()
        .name(format!("accept-{rank}"))
        .spawn(move || {
            while !shared.stop.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok(mut s) => {
                        if let Some(peer) = admit(&mut s, rank, n, &config, &shared) {
                            let mut map = shared.streams.lock().expect("streams lock");
                            map.insert(peer, s);
                            shared.cv.notify_all();
                        }
                    }
                    Err(e) if e.kind() == io::ErrorKind::WouldBlock => thread::sleep(POLL),
                    Err(_) => thread::sleep(POLL),
                }
            }
        })
        .map_err(|e| format!("spawn accept thread: {e}"))
}

/// Read + check the Hello on a fresh connection; `Some(peer_rank)` if
/// the dialer belongs to this cluster.
fn admit(s: &mut Stream, rank: usize, n: usize, config: &str, shared: &Shared) -> Option<usize> {
    let _ = s.set_read_timeout(Some(POLL));
    let deadline = Instant::now() + Duration::from_secs(5);
    let stop = || shared.stop.load(Ordering::Relaxed) || Instant::now() >= deadline;
    match read_frame(s, &stop) {
        Ok(FrameIn::Msg(payload)) => match decode(&payload) {
            // The dialer is always the lower rank of the pair.
            Ok(ClusterMsg::Hello(h))
                if h.nodes == n && h.config == config && h.rank < rank =>
            {
                Some(h.rank)
            }
            _ => None,
        },
        _ => None,
    }
}

/// The [`Transport`] the cluster node installs on its engine: rank r's
/// own broadcasts go out as frames; neighbors' broadcasts are received,
/// decoded, and substituted for the locally computed copy. During a
/// rejoin's checkpoint replay (`t < mute_until`) the node is down in
/// every replica's fault plan, so the transport goes silent — no sends,
/// no receives — and the replay is pure local recomputation.
pub struct SocketTransport {
    links: Links,
    mute_until: u64,
}

impl SocketTransport {
    pub fn new(links: Links, mute_until: u64) -> SocketTransport {
        SocketTransport { links, mute_until }
    }

    pub fn stats(&self) -> WireSnapshot {
        self.links.stats()
    }

    pub fn links_mut(&mut self) -> &mut Links {
        &mut self.links
    }
}

impl Transport for SocketTransport {
    fn exchange(
        &mut self,
        t: u64,
        from: usize,
        q: &SparseVec,
        d: usize,
        neighbors: &[usize],
    ) -> Option<SparseVec> {
        if t < self.mute_until {
            return None;
        }
        let rank = self.links.rank();
        if from == rank {
            let payload = encode_data(t, from, &encode_sparse(q, d));
            for &p in neighbors {
                if p != rank {
                    self.links.send_to(p, &payload);
                }
            }
            return None;
        }
        if !neighbors.contains(&rank) {
            return None;
        }
        let body = self.links.recv_data(from, t)?;
        match decode_sparse(&body, d) {
            Ok(received) => {
                if &received != q {
                    // Replica divergence: substitute the sender's copy
                    // (what physically happened) and surface the drift.
                    bump(&self.links.shared.stats.mismatches);
                }
                Some(received)
            }
            Err(_) => {
                bump(&self.links.shared.stats.fallbacks);
                None
            }
        }
    }

    fn describe(&self) -> String {
        format!(
            "{} rank {}/{}",
            self.links.kind().as_str(),
            self.links.rank(),
            self.links.n()
        )
    }
}

#[cfg(all(test, unix))]
mod tests {
    use super::*;

    fn tmp_dir(tag: &str) -> PathBuf {
        let pid = std::process::id();
        let d = std::env::temp_dir().join(format!("sparq-links-{tag}-{pid}"));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).expect("mkdir");
        d
    }

    fn pair(dir: &Path, timeout: Duration) -> (Links, Links) {
        let mk = |rank| {
            Links::bind(dir, rank, 2, SocketKind::Uds, "127.0.0.1", "cfg", timeout)
                .expect("bind")
        };
        (mk(0), mk(1))
    }

    #[test]
    fn broadcasts_cross_the_socket_both_directions() {
        let dir = tmp_dir("xchg");
        let (a, b) = pair(&dir, Duration::from_secs(10));
        let d = 32;
        let mut q0 = SparseVec::new();
        q0.push(1, 0.5);
        q0.push(30, -4.0);
        let mut q1 = SparseVec::new();
        q1.push(7, 2.25);
        let b0 = encode_sparse(&q0, d);
        let b1 = encode_sparse(&q1, d);
        // rank 0 (dialer) → rank 1 and back on the same stream, for a
        // few rounds to exercise stream reuse.
        let (b0a, b1a) = (b0.clone(), b1.clone());
        let h = thread::spawn(move || {
            for t in 0..3u64 {
                assert!(a.send_to(1, &encode_data(t, 0, &b0a)));
                assert_eq!(a.recv_data(1, t).expect("recv from 1"), b1a);
            }
            a.stats()
        });
        for t in 0..3u64 {
            assert_eq!(b.recv_data(0, t).expect("recv from 0"), b0);
            assert!(b.send_to(0, &encode_data(t, 1, &b1)));
        }
        let sa = h.join().expect("join");
        assert_eq!(sa.fallbacks, 0);
        assert_eq!(b.stats().fallbacks, 0);
        assert!(sa.frames_sent >= 3);
        drop(b);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn stale_frames_are_dropped_and_missing_peers_fall_back() {
        let dir = tmp_dir("stale");
        let (a, b) = pair(&dir, Duration::from_millis(400));
        let d = 8;
        let mut q = SparseVec::new();
        q.push(2, 1.0);
        let body = encode_sparse(&q, d);
        // Send rounds 0 and 1; the receiver asks for round 1 and must
        // skip the stale round-0 frame.
        let h = thread::spawn({
            let p0 = encode_data(0, 0, &body);
            let p1 = encode_data(1, 0, &body);
            move || {
                assert!(a.send_to(1, &p0));
                assert!(a.send_to(1, &p1));
                a
            }
        });
        assert_eq!(b.recv_data(0, 1).expect("round 1"), body);
        let a = h.join().expect("join");
        assert_eq!(b.stats().stale_drops, 1);
        drop(a);
        // After a's listener is gone, b (acceptor side for peer 0)
        // times out waiting for a dial.
        assert!(b.recv_data(0, 2).is_none());
        assert!(b.stats().fallbacks >= 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn socket_transport_substitutes_the_received_copy() {
        let dir = tmp_dir("transport");
        let (a, b) = pair(&dir, Duration::from_secs(10));
        let d = 16;
        let mut q = SparseVec::new();
        q.push(3, -1.5);
        q.push(15, 0.25);
        let q_for_sender = q.clone();
        let h = thread::spawn(move || {
            let mut ta = SocketTransport::new(a, 0);
            // Sender role: returns None, frame goes out.
            assert!(ta.exchange(5, 0, &q_for_sender, d, &[1]).is_none());
            ta
        });
        let mut tb = SocketTransport::new(b, 0);
        // Receiver role: substitution returns the decoded copy, equal
        // bit-for-bit to the local one.
        let got = tb.exchange(5, 0, &q, d, &[1]).expect("substitute");
        assert_eq!(got, q);
        assert_eq!(tb.stats().mismatches, 0);
        // Bystander role and muted replay return None without I/O.
        assert!(tb.exchange(5, 0, &q, d, &[]).is_none());
        let mdir = tmp_dir("muted");
        let mut muted = SocketTransport::new(
            Links::bind(
                &mdir,
                0,
                2,
                SocketKind::Uds,
                "127.0.0.1",
                "cfg",
                Duration::from_millis(100),
            )
            .expect("bind"),
            10,
        );
        assert!(muted.exchange(3, 1, &q, d, &[0]).is_none());
        assert_eq!(muted.stats().fallbacks, 0);
        drop(muted);
        let ta = h.join().expect("join");
        assert!(ta.describe().starts_with("uds rank 0/2"));
        drop(ta);
        drop(tb);
        let _ = std::fs::remove_dir_all(&dir);
        let _ = std::fs::remove_dir_all(&mdir);
    }
}
