//! Engine-equivalence suite (ISSUE 2): the policy-driven
//! `DecentralizedEngine` must reproduce the three *seed* coordinators —
//! SPARQ, CHOCO, vanilla D-PSGD — bit-for-bit on fixed seeds.
//!
//! The seed step loops were deleted in the refactor, so they are
//! re-implemented here, verbatim, as sequential reference coordinators
//! built from the same public primitives (`NodeState`,
//! `NeighborAccumulator`, `Compressor`, `EventTrigger`). Every scenario
//! steps the engine and its reference in lockstep and asserts exact
//! equality of per-node parameters, x̄, bus counters (bits, messages,
//! rounds, per-node bits), and fired counts at every eval point.
//!
//! Also pinned here: the new scenario layers are deterministic — lossy
//! links and sampled-gossip topologies produce identical series for any
//! worker count (link coins are stateless hashes; topology sampling
//! derives a fresh seeded stream per round).

use sparq::comm::Bus;
use sparq::compress::{Compressor, SignTopK, TopK};
use sparq::config::ExperimentConfig;
use sparq::coordinator::node::NodeState;
use sparq::coordinator::{
    ChocoSgd, DecentralizedAlgo, NeighborAccumulator, SparqConfig, SparqSgd,
    VanillaDecentralized,
};
use sparq::experiments::run_config;
use sparq::graph::{uniform_neighbor, MixingMatrix, SpectralInfo, Topology, TopologyKind};
use sparq::linalg::sub_into;
use sparq::problems::{GradientSource, QuadraticProblem};
use sparq::schedule::{LrSchedule, SyncSchedule};
use sparq::trigger::{EventTrigger, ThresholdSchedule};
use sparq::util::Rng;

// ---------------------------------------------------------------------
// Seed reference coordinators (verbatim re-implementations of the
// pre-engine step bodies, sequential / workers = 1 semantics)
// ---------------------------------------------------------------------

struct SeedSparq {
    mixing: MixingMatrix,
    compressor: Box<dyn Compressor>,
    trigger: EventTrigger,
    lr: LrSchedule,
    sync: SyncSchedule,
    gamma: f64,
    momentum: f32,
    nodes: Vec<NodeState>,
    xhat: Vec<Vec<f32>>,
    nbr: NeighborAccumulator,
    total_fired: u64,
    total_checks: u64,
    fired_last: usize,
}

#[allow(clippy::too_many_arguments)]
impl SeedSparq {
    fn new(
        mixing: MixingMatrix,
        compressor: Box<dyn Compressor>,
        trigger: EventTrigger,
        lr: LrSchedule,
        sync: SyncSchedule,
        momentum: f32,
        seed: u64,
        d: usize,
    ) -> SeedSparq {
        let n = mixing.n();
        let spectral = SpectralInfo::compute(&mixing);
        let gamma =
            spectral.gamma_tuned(compressor.omega(d), compressor.effective_omega(d));
        let mut root = Rng::new(seed);
        let nodes = (0..n)
            .map(|i| NodeState::new(d, momentum > 0.0, root.fork(i as u64)))
            .collect();
        let nbr = NeighborAccumulator::new(&mixing, d);
        SeedSparq {
            mixing,
            compressor,
            trigger,
            lr,
            sync,
            gamma,
            momentum,
            nodes,
            xhat: vec![vec![0.0; d]; n],
            nbr,
            total_fired: 0,
            total_checks: 0,
            fired_last: 0,
        }
    }

    fn step(&mut self, t: u64, src: &mut dyn GradientSource, bus: &mut Bus) {
        let n = self.nodes.len();
        let eta64 = self.lr.eta(t);
        let eta = eta64 as f32;

        // lines 3–4: gradient + local half-step, every node
        for (i, node) in self.nodes.iter_mut().enumerate() {
            let x = std::mem::take(&mut node.x);
            src.grad(i, &x, &mut node.rng, &mut node.grad);
            node.x = x;
            node.local_step(eta, self.momentum);
        }

        if self.sync.is_sync(t) {
            // lines 7–9: trigger check + compress against pre-update x̂
            for (i, node) in self.nodes.iter_mut().enumerate() {
                node.fired = self.trigger.fires(&node.x_half, &self.xhat[i], t, eta64);
                if node.fired {
                    sub_into(&node.x_half, &self.xhat[i], &mut node.diff);
                    self.compressor
                        .compress_sparse(&node.diff, &mut node.rng, &mut node.q);
                }
            }

            // lines 9–13: charge broadcasts + estimate updates, node order
            let d = self.xhat[0].len();
            self.total_checks += n as u64;
            let mut fired_count = 0usize;
            for i in 0..n {
                if !self.nodes[i].fired {
                    continue;
                }
                fired_count += 1;
                let q = &self.nodes[i].q;
                let bits = self.compressor.message_bits(d, q.nnz());
                bus.charge_broadcast(i, self.mixing.topology.degree(i), bits);
                q.add_to(&mut self.xhat[i]);
                self.nbr.apply_broadcast(i, q);
            }
            self.fired_last = fired_count;
            self.total_fired += fired_count as u64;

            // line 15: consensus commit
            let gamma = self.gamma as f32;
            for (i, node) in self.nodes.iter_mut().enumerate() {
                std::mem::swap(&mut node.x, &mut node.x_half);
                self.nbr.commit(i, gamma, &self.xhat[i], &mut node.x);
            }
        } else {
            // line 17: local step only
            for node in self.nodes.iter_mut() {
                std::mem::swap(&mut node.x, &mut node.x_half);
            }
            self.fired_last = 0;
        }
        bus.end_round();
    }
}

struct SeedChoco {
    mixing: MixingMatrix,
    compressor: Box<dyn Compressor>,
    lr: LrSchedule,
    gamma: f64,
    momentum: f32,
    nodes: Vec<NodeState>,
    xhat: Vec<Vec<f32>>,
    nbr: NeighborAccumulator,
}

impl SeedChoco {
    fn new(
        mixing: MixingMatrix,
        compressor: Box<dyn Compressor>,
        lr: LrSchedule,
        momentum: f32,
        d: usize,
        seed: u64,
    ) -> SeedChoco {
        let n = mixing.n();
        let spectral = SpectralInfo::compute(&mixing);
        let gamma =
            spectral.gamma_tuned(compressor.omega(d), compressor.effective_omega(d));
        let mut root = Rng::new(seed);
        let nodes = (0..n)
            .map(|i| NodeState::new(d, momentum > 0.0, root.fork(i as u64)))
            .collect();
        let nbr = NeighborAccumulator::new(&mixing, d);
        SeedChoco {
            mixing,
            compressor,
            lr,
            gamma,
            momentum,
            nodes,
            xhat: vec![vec![0.0; d]; n],
            nbr,
        }
    }

    fn step(&mut self, t: u64, src: &mut dyn GradientSource, bus: &mut Bus) {
        let n = self.nodes.len();
        let eta = self.lr.eta(t) as f32;

        for (i, node) in self.nodes.iter_mut().enumerate() {
            let x = std::mem::take(&mut node.x);
            src.grad(i, &x, &mut node.rng, &mut node.grad);
            node.x = x;
            node.local_step(eta, self.momentum);
        }

        // every node transmits every round (the CHOCO contract)
        for (i, node) in self.nodes.iter_mut().enumerate() {
            sub_into(&node.x_half, &self.xhat[i], &mut node.diff);
            self.compressor
                .compress_sparse(&node.diff, &mut node.rng, &mut node.q);
        }

        let d = self.xhat[0].len();
        for i in 0..n {
            let q = &self.nodes[i].q;
            let bits = self.compressor.message_bits(d, q.nnz());
            bus.charge_broadcast(i, self.mixing.topology.degree(i), bits);
            q.add_to(&mut self.xhat[i]);
            self.nbr.apply_broadcast(i, q);
        }

        let gamma = self.gamma as f32;
        for (i, node) in self.nodes.iter_mut().enumerate() {
            std::mem::swap(&mut node.x, &mut node.x_half);
            self.nbr.commit(i, gamma, &self.xhat[i], &mut node.x);
        }
        bus.end_round();
    }
}

struct SeedVanilla {
    mixing: MixingMatrix,
    lr: LrSchedule,
    momentum: f32,
    nodes: Vec<NodeState>,
    mixed: Vec<Vec<f32>>,
}

impl SeedVanilla {
    fn new(
        mixing: MixingMatrix,
        lr: LrSchedule,
        momentum: f32,
        d: usize,
        seed: u64,
    ) -> SeedVanilla {
        let n = mixing.n();
        let mut root = Rng::new(seed);
        let nodes = (0..n)
            .map(|i| NodeState::new(d, momentum > 0.0, root.fork(i as u64)))
            .collect();
        SeedVanilla {
            mixing,
            lr,
            momentum,
            nodes,
            mixed: vec![vec![0.0; d]; n],
        }
    }

    fn step(&mut self, t: u64, src: &mut dyn GradientSource, bus: &mut Bus) {
        let n = self.nodes.len();
        let d = self.nodes[0].x.len();
        let eta = self.lr.eta(t) as f32;

        // gradients at current params (applied after mixing below)
        for (i, node) in self.nodes.iter_mut().enumerate() {
            let x = std::mem::take(&mut node.x);
            src.grad(i, &x, &mut node.rng, &mut node.grad);
            node.x = x;
        }

        for i in 0..n {
            bus.charge_broadcast(i, self.mixing.topology.degree(i), 32 * d as u64);
        }
        for i in 0..n {
            let wii = self.mixing.weight(i, i) as f32;
            let row = &mut self.mixed[i];
            for (m, x) in row.iter_mut().zip(self.nodes[i].x.iter()) {
                *m = wii * x;
            }
            for &j in &self.mixing.topology.neighbors[i] {
                let w = self.mixing.weight(i, j) as f32;
                for (m, x) in row.iter_mut().zip(self.nodes[j].x.iter()) {
                    *m += w * x;
                }
            }
        }

        let momentum = self.momentum;
        for (i, node) in self.nodes.iter_mut().enumerate() {
            match node.momentum.as_mut() {
                Some(m) => {
                    for ((x, mi), (g, mix)) in node
                        .x
                        .iter_mut()
                        .zip(m.iter_mut())
                        .zip(node.grad.iter().zip(self.mixed[i].iter()))
                    {
                        *mi = momentum * *mi + g;
                        *x = mix - eta * *mi;
                    }
                }
                None => {
                    for (x, (g, mix)) in node
                        .x
                        .iter_mut()
                        .zip(node.grad.iter().zip(self.mixed[i].iter()))
                    {
                        *x = mix - eta * g;
                    }
                }
            }
        }
        bus.end_round();
    }
}

// ---------------------------------------------------------------------
// Equivalence scenarios
// ---------------------------------------------------------------------

fn ring_mixing(n: usize) -> MixingMatrix {
    uniform_neighbor(&Topology::new(TopologyKind::Ring, n, 0))
}

fn quad(d: usize, n: usize, seed: u64) -> QuadraticProblem {
    QuadraticProblem::new(d, n, 0.5, 2.0, 0.05, 1.0, seed)
}

#[test]
fn sparq_engine_reproduces_seed_coordinator_bit_for_bit() {
    let (n, d, steps, seed) = (8usize, 48usize, 300u64, 17u64);
    let lr = LrSchedule::InverseTime { a: 60.0, b: 2.0 };
    let trig = ThresholdSchedule::Constant(5.0);

    let mut engine = SparqSgd::new(
        SparqConfig {
            mixing: ring_mixing(n),
            compressor: Box::new(SignTopK::new(6)),
            trigger: EventTrigger::new(trig.clone()),
            lr: lr.clone(),
            sync: SyncSchedule::EveryH(2),
            gamma: None,
            momentum: 0.0,
            seed,
        },
        d,
    );
    let mut seed_ref = SeedSparq::new(
        ring_mixing(n),
        Box::new(SignTopK::new(6)),
        EventTrigger::new(trig),
        lr,
        SyncSchedule::EveryH(2),
        0.0,
        seed,
        d,
    );
    assert_eq!(engine.gamma, seed_ref.gamma, "tuned γ diverged");

    let mut prob_a = quad(d, n, 99);
    let mut prob_b = quad(d, n, 99);
    let mut bus_a = Bus::new(n);
    let mut bus_b = Bus::new(n);
    for t in 0..steps {
        engine.step(t, &mut prob_a, &mut bus_a);
        seed_ref.step(t, &mut prob_b, &mut bus_b);
        if (t + 1) % 25 == 0 || t + 1 == steps {
            for i in 0..n {
                assert_eq!(
                    engine.params(i),
                    &seed_ref.nodes[i].x[..],
                    "t={t} node {i}: params diverged"
                );
                assert_eq!(
                    engine.xhat(i),
                    &seed_ref.xhat[i][..],
                    "t={t} node {i}: estimates diverged"
                );
            }
            assert_eq!(engine.last_fired(), seed_ref.fired_last, "t={t}");
            assert_eq!(bus_a.total_bits, bus_b.total_bits, "t={t}: bits diverged");
            // identical x̄ ⇒ identical evaluated loss at this point
            let bar_a = engine.x_bar();
            let loss_a = prob_a.global_loss(&bar_a);
            let mut bar_b = vec![0.0f32; d];
            for i in 0..n {
                for (b, v) in bar_b.iter_mut().zip(seed_ref.nodes[i].x.iter()) {
                    *b += v;
                }
            }
            for b in bar_b.iter_mut() {
                *b /= n as f32;
            }
            assert_eq!(bar_a, bar_b, "t={t}: x̄ diverged");
            assert_eq!(loss_a, prob_b.global_loss(&bar_b), "t={t}: loss diverged");
        }
    }
    assert_eq!(engine.total_fired, seed_ref.total_fired);
    assert_eq!(engine.total_checks, seed_ref.total_checks);
    assert_eq!(bus_a.total_messages, bus_b.total_messages);
    assert_eq!(bus_a.comm_rounds, bus_b.comm_rounds);
    assert_eq!(bus_a.node_bits, bus_b.node_bits);
    // the scenario actually exercised the trigger both ways
    assert!(engine.total_fired > 0);
    assert!(engine.total_fired < engine.total_checks);
}

#[test]
fn sparq_engine_matches_seed_with_stochastic_compressor_and_momentum() {
    // QsgdTopK draws compressor randomness from the node RNG streams and
    // momentum exercises the heavy-ball half-step — both must line up.
    let (n, d, steps, seed) = (6usize, 40usize, 400u64, 23u64);
    let lr = LrSchedule::InverseTime { a: 80.0, b: 2.0 };
    let trig = ThresholdSchedule::Poly { c0: 5.0, eps: 0.5 };

    let mut engine = SparqSgd::new(
        SparqConfig {
            mixing: ring_mixing(n),
            compressor: sparq::compress::parse("qsgd_topk:8:4", d).unwrap(),
            trigger: EventTrigger::new(trig.clone()),
            lr: lr.clone(),
            sync: SyncSchedule::EveryH(5),
            gamma: None,
            momentum: 0.9,
            seed,
        },
        d,
    );
    let mut seed_ref = SeedSparq::new(
        ring_mixing(n),
        sparq::compress::parse("qsgd_topk:8:4", d).unwrap(),
        EventTrigger::new(trig),
        lr,
        SyncSchedule::EveryH(5),
        0.9,
        seed,
        d,
    );

    let mut prob_a = quad(d, n, 5);
    let mut prob_b = quad(d, n, 5);
    let mut bus_a = Bus::new(n);
    let mut bus_b = Bus::new(n);
    for t in 0..steps {
        engine.step(t, &mut prob_a, &mut bus_a);
        seed_ref.step(t, &mut prob_b, &mut bus_b);
    }
    for i in 0..n {
        assert_eq!(engine.params(i), &seed_ref.nodes[i].x[..], "node {i}");
        assert_eq!(
            engine.momentum(i).unwrap(),
            seed_ref.nodes[i].momentum.as_deref().unwrap(),
            "node {i} momentum"
        );
    }
    assert_eq!(bus_a.total_bits, bus_b.total_bits);
    assert_eq!(bus_a.node_bits, bus_b.node_bits);
    assert_eq!(engine.total_fired, seed_ref.total_fired);
    assert!(bus_a.total_bits > 0);
}

#[test]
fn choco_engine_reproduces_seed_coordinator_bit_for_bit() {
    let (n, d, steps, seed) = (8usize, 32usize, 250u64, 31u64);
    let lr = LrSchedule::InverseTime { a: 50.0, b: 2.0 };

    let mut engine = ChocoSgd::new(
        ring_mixing(n),
        Box::new(TopK::new(6)),
        lr.clone(),
        0.0,
        d,
        seed,
    );
    let mut seed_ref =
        SeedChoco::new(ring_mixing(n), Box::new(TopK::new(6)), lr, 0.0, d, seed);
    assert_eq!(engine.gamma, seed_ref.gamma);

    let mut prob_a = quad(d, n, 7);
    let mut prob_b = quad(d, n, 7);
    let mut bus_a = Bus::new(n);
    let mut bus_b = Bus::new(n);
    for t in 0..steps {
        engine.step(t, &mut prob_a, &mut bus_a);
        seed_ref.step(t, &mut prob_b, &mut bus_b);
        if (t + 1) % 50 == 0 {
            for i in 0..n {
                assert_eq!(engine.params(i), &seed_ref.nodes[i].x[..], "t={t} node {i}");
            }
        }
    }
    assert_eq!(bus_a.total_bits, bus_b.total_bits);
    assert_eq!(bus_a.total_messages, bus_b.total_messages);
    assert_eq!(bus_a.comm_rounds, bus_b.comm_rounds);
    assert_eq!(bus_a.node_bits, bus_b.node_bits);
    assert_eq!(engine.last_fired(), n); // everyone transmits
}

#[test]
fn vanilla_engine_reproduces_seed_coordinator_bit_for_bit() {
    let (n, d, steps, seed) = (6usize, 28usize, 200u64, 41u64);
    let lr = LrSchedule::Constant(0.05);

    let mut engine = VanillaDecentralized::new(ring_mixing(n), lr.clone(), 0.9, d, seed);
    let mut seed_ref = SeedVanilla::new(ring_mixing(n), lr, 0.9, d, seed);

    let mut prob_a = quad(d, n, 13);
    let mut prob_b = quad(d, n, 13);
    let mut bus_a = Bus::new(n);
    let mut bus_b = Bus::new(n);
    for t in 0..steps {
        engine.step(t, &mut prob_a, &mut bus_a);
        seed_ref.step(t, &mut prob_b, &mut bus_b);
        if (t + 1) % 40 == 0 {
            for i in 0..n {
                assert_eq!(engine.params(i), &seed_ref.nodes[i].x[..], "t={t} node {i}");
                assert_eq!(
                    engine.momentum(i).unwrap(),
                    seed_ref.nodes[i].momentum.as_deref().unwrap(),
                    "t={t} node {i} momentum"
                );
            }
        }
    }
    assert_eq!(bus_a.total_bits, bus_b.total_bits);
    assert_eq!(bus_a.total_messages, bus_b.total_messages);
    assert_eq!(bus_a.node_bits, bus_b.node_bits);
    assert!(bus_a.total_bits > 0);
}

// ---------------------------------------------------------------------
// Determinism of the new scenario layers across worker counts
// ---------------------------------------------------------------------

#[test]
fn lossy_link_run_is_deterministic_across_worker_counts() {
    let mk = |workers: usize| ExperimentConfig {
        nodes: 8,
        steps: 200,
        eval_every: 50,
        problem: "quadratic:48".into(),
        trigger: "const:20".into(),
        h: sparq::config::SyncSpec::every(2),
        link: "drop:0.25+straggler:1:0.5".into(),
        workers,
        ..Default::default()
    };
    let a = run_config(&mk(1), false);
    let b = run_config(&mk(8), false);
    assert_eq!(a.to_csv(), b.to_csv(), "lossy-link series diverged");
    // and the faults actually engaged: fewer bits than the ideal run
    let ideal = run_config(
        &ExperimentConfig {
            link: "none".into(),
            ..mk(1)
        },
        false,
    );
    let lossy_bits = a.records.last().unwrap().bits;
    let ideal_bits = ideal.records.last().unwrap().bits;
    assert!(lossy_bits < ideal_bits, "{lossy_bits} vs {ideal_bits}");
}

#[test]
fn sampled_gossip_run_is_deterministic_across_worker_counts() {
    let mk = |workers: usize| ExperimentConfig {
        nodes: 16,
        steps: 150,
        eval_every: 50,
        problem: "quadratic:32".into(),
        trigger: "zero".into(),
        h: sparq::config::SyncSpec::every(2),
        topology_schedule: "sample:torus:6".into(),
        workers,
        ..Default::default()
    };
    let a = run_config(&mk(1), false);
    let b = run_config(&mk(8), false);
    assert_eq!(a.to_csv(), b.to_csv(), "sampled-gossip series diverged");
    assert!(a.records.last().unwrap().bits > 0);
}

// ---------------------------------------------------------------------
// Algorithm-family compositions: degeneracy pins + worker determinism
// ---------------------------------------------------------------------

/// SQuARM with β = 0 must be *exactly* SPARQ: the momentum buffer then
/// holds u = 0·u + diff = diff, so the trigger sees the identical norm
/// and the transmitted value C(diff) is unchanged. The kernel path is
/// shared (`scale_add_into_dist2(0, …)` ≡ `sub_into_dist2`), so the
/// whole series — loss, bits, fired counts — is bit-identical.
#[test]
fn squarm_with_zero_beta_is_bitwise_equivalent_to_sparq() {
    let base = ExperimentConfig {
        nodes: 8,
        steps: 300,
        eval_every: 50,
        problem: "quadratic:48".into(),
        compressor: "sign_topk:25%".into(),
        trigger: "const:20".into(),
        h: sparq::config::SyncSpec::every(2),
        ..Default::default()
    };
    let squarm0 = ExperimentConfig {
        family: "squarm:0".into(),
        ..base.clone()
    };
    assert_eq!(
        run_config(&base, false).to_csv(),
        run_config(&squarm0, false).to_csv(),
        "squarm(β=0) must be bit-identical to sparq"
    );
    // …and a real β actually buffers drift across skipped broadcasts:
    // the firing pattern (and therefore the series) must change.
    let squarm9 = ExperimentConfig {
        family: "squarm:0.9".into(),
        ..base.clone()
    };
    assert_ne!(
        run_config(&base, false).to_csv(),
        run_config(&squarm9, false).to_csv(),
        "squarm(β=0.9) should not coincide with sparq on this workload"
    );
}

/// A per-coordinate trigger with threshold 0 masks only exactly-zero
/// coordinates and fires whenever any coordinate is nonzero — the same
/// firing condition as the norm trigger at threshold 0, with the fired
/// coordinates entering the compressor verbatim. Bit-identical series.
#[test]
fn degenerate_per_coordinate_trigger_matches_the_norm_trigger_bitwise() {
    let base = ExperimentConfig {
        nodes: 8,
        steps: 250,
        eval_every: 50,
        problem: "quadratic:32".into(),
        compressor: "sign_topk:25%".into(),
        trigger: "zero".into(),
        h: sparq::config::SyncSpec::every(2),
        ..Default::default()
    };
    let percoord = ExperimentConfig {
        trigger: "percoord:0".into(),
        ..base.clone()
    };
    assert_eq!(
        run_config(&base, false).to_csv(),
        run_config(&percoord, false).to_csv(),
        "percoord:0 must be bit-identical to the norm trigger at 0"
    );
    // …and a positive per-coordinate threshold really masks: the
    // compressor then sees a sparser diff and the series departs.
    let masked = ExperimentConfig {
        trigger: "percoord:5".into(),
        ..base.clone()
    };
    assert_ne!(
        run_config(&base, false).to_csv(),
        run_config(&masked, false).to_csv(),
        "percoord:5 should mask coordinates on this workload"
    );
}

#[test]
fn family_runs_are_deterministic_across_worker_counts() {
    let mk = |family: &str, trigger: &str, workers: usize| ExperimentConfig {
        nodes: 8,
        steps: 200,
        eval_every: 50,
        problem: "quadratic:32".into(),
        compressor: "sign_topk:25%".into(),
        family: family.into(),
        trigger: trigger.into(),
        h: sparq::config::SyncSpec::every(2),
        workers,
        ..Default::default()
    };
    let a = run_config(&mk("squarm:0.9", "const:20", 1), false);
    let b = run_config(&mk("squarm:0.9", "const:20", 8), false);
    assert_eq!(a.to_csv(), b.to_csv(), "squarm series diverged across worker counts");
    let a = run_config(&mk("sparq", "percoord:2.5", 1), false);
    let b = run_config(&mk("sparq", "percoord:2.5", 8), false);
    assert_eq!(a.to_csv(), b.to_csv(), "percoord series diverged across worker counts");
}

#[test]
fn static_schedule_default_is_bitwise_equivalent_to_topology_field() {
    // "static" must change nothing relative to the plain topology path.
    let base = ExperimentConfig {
        nodes: 8,
        steps: 120,
        eval_every: 40,
        problem: "quadratic:24".into(),
        ..Default::default()
    };
    let explicit = ExperimentConfig {
        topology_schedule: "static".into(),
        link: "none".into(),
        ..base.clone()
    };
    assert_eq!(
        run_config(&base, false).to_csv(),
        run_config(&explicit, false).to_csv()
    );
}
