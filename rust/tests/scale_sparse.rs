//! Sparse-mixing equivalence suite (PR 7): the O(|E|) edge-aligned
//! `MixingMatrix` and the iterative spectral path must be *invisible* at
//! paper scale — bit-identical weights, series, and config identity —
//! while actually scaling to thousands of nodes.
//!
//! Pinned here:
//! * sparse constructors vs an in-test dense reference (the pre-refactor
//!   n×n loops, replicated verbatim) — exact f64 equality on every entry
//!   for both constructions on all seven topology kinds;
//! * Lanczos vs Jacobi: `compute_iterative` agrees with `compute_dense`
//!   to 1e-8 on small graphs (the tolerance contract EXPERIMENTS.md
//!   §Scale documents);
//! * engine series bit-identity across worker counts per topology kind,
//!   through a topology switch, and under a chaos plan (crash +
//!   partition + corruption) — fused trigger pass, block-claimed pool,
//!   and CSR staleness table included;
//! * O(|E|) storage and a full construction + spectral solve at n = 4096
//!   (the dense path would allocate ~128 MB and run an O(n³) Jacobi).

use sparq::comm::{Bus, FaultPlan};
use sparq::compress::SignTopK;
use sparq::config::ExperimentConfig;
use sparq::coordinator::{DecentralizedAlgo, SparqConfig, SparqSgd};
use sparq::experiments::run_config;
use sparq::graph::{
    metropolis_hastings, uniform_neighbor, MixingMatrix, SpectralInfo, Topology, TopologyKind,
};
use sparq::problems::QuadraticProblem;
use sparq::schedule::{LrSchedule, SyncSchedule};
use sparq::sweep::config_hash;
use sparq::trigger::{EventTrigger, ThresholdSchedule};

const ALL_KINDS: [(TopologyKind, usize); 7] = [
    (TopologyKind::Ring, 12),
    (TopologyKind::Complete, 8),
    (TopologyKind::Star, 9),
    (TopologyKind::Path, 7),
    (TopologyKind::Torus, 16),
    (TopologyKind::Hypercube, 16),
    (TopologyKind::RandomRegular(4), 14),
];

// ---------------------------------------------------------------------
// Weights: sparse storage vs the historical dense construction
// ---------------------------------------------------------------------

/// The pre-refactor dense Metropolis–Hastings rows: fill edge weights
/// into an n-vector, then take the diagonal as 1 − (full-row sum, which
/// only adds structural zeros — ascending-j order).
fn dense_mh(t: &Topology) -> Vec<Vec<f64>> {
    let n = t.n;
    let mut w = vec![vec![0.0f64; n]; n];
    for i in 0..n {
        for &j in &t.neighbors[i] {
            w[i][j] = 1.0 / (1.0 + t.degree(i).max(t.degree(j)) as f64);
        }
        let off: f64 = w[i].iter().sum();
        w[i][i] = 1.0 - off;
    }
    w
}

/// The pre-refactor dense uniform-neighbor rows (share = 1/(Δ+1),
/// self-weight absorbs the remainder as 1 − deg·share).
fn dense_uniform(t: &Topology) -> Vec<Vec<f64>> {
    let n = t.n;
    let share = 1.0 / (t.max_degree() as f64 + 1.0);
    let mut w = vec![vec![0.0f64; n]; n];
    for i in 0..n {
        for &j in &t.neighbors[i] {
            w[i][j] = share;
        }
        w[i][i] = 1.0 - t.degree(i) as f64 * share;
    }
    w
}

fn assert_entries_bit_equal(mm: &MixingMatrix, dense: &[Vec<f64>], label: &str) {
    let n = mm.n();
    for i in 0..n {
        for j in 0..n {
            let (s, d) = (mm.weight(i, j), dense[i][j]);
            assert_eq!(s.to_bits(), d.to_bits(), "{label}: w[{i}][{j}] sparse {s} != dense {d}");
        }
    }
}

#[test]
fn sparse_weights_bit_match_dense_reference_on_all_kinds() {
    for (kind, n) in ALL_KINDS {
        let t = Topology::new(kind, n, 3);
        let mh = metropolis_hastings(&t);
        mh.validate().unwrap();
        assert_entries_bit_equal(&mh, &dense_mh(&t), &format!("{kind:?} MH"));

        let un = uniform_neighbor(&t);
        un.validate().unwrap();
        assert_entries_bit_equal(&un, &dense_uniform(&t), &format!("{kind:?} uniform"));
    }
}

// ---------------------------------------------------------------------
// Spectral: Lanczos vs Jacobi tolerance contract
// ---------------------------------------------------------------------

#[test]
fn iterative_spectral_matches_dense_within_1e8_on_small_graphs() {
    for (kind, n) in [
        (TopologyKind::Ring, 24),
        (TopologyKind::Torus, 64),
        (TopologyKind::Hypercube, 64),
        (TopologyKind::RandomRegular(4), 64),
    ] {
        for mm in [
            uniform_neighbor(&Topology::new(kind, n, 5)),
            metropolis_hastings(&Topology::new(kind, n, 5)),
        ] {
            let d = SpectralInfo::compute_dense(&mm);
            let i = SpectralInfo::compute_iterative(&mm);
            assert!((i.lambda1 - 1.0).abs() < 1e-8, "{kind:?}: λ₁={}", i.lambda1);
            assert!(
                (d.lambda2_abs - i.lambda2_abs).abs() < 1e-8,
                "{kind:?}: |λ₂| dense {} vs iterative {}",
                d.lambda2_abs,
                i.lambda2_abs
            );
            assert!(
                (d.delta - i.delta).abs() < 1e-8,
                "{kind:?}: δ dense {} vs iterative {}",
                d.delta,
                i.delta
            );
            assert!(
                (d.beta - i.beta).abs() < 1e-8,
                "{kind:?}: β dense {} vs iterative {}",
                d.beta,
                i.beta
            );
        }
    }
}

// ---------------------------------------------------------------------
// Engine series: bit-identity across worker counts
// ---------------------------------------------------------------------

fn series_cfg(topology: &str, workers: usize) -> ExperimentConfig {
    ExperimentConfig {
        nodes: 16,
        steps: 150,
        eval_every: 50,
        problem: "quadratic:32".into(),
        topology: topology.into(),
        trigger: "const:20".into(),
        h: sparq::config::SyncSpec::every(2),
        workers,
        ..Default::default()
    }
}

#[test]
fn series_bit_identical_across_worker_counts_per_topology() {
    // The fused trigger→compress pass and block-claimed pool must not
    // perturb any topology's trajectory: per-node RNGs and sequential
    // cross-node commits make the schedule of threads irrelevant.
    for topology in ["ring", "complete", "star", "path", "torus", "hypercube", "regular4"] {
        let a = run_config(&series_cfg(topology, 1), false);
        let b = run_config(&series_cfg(topology, 8), false);
        assert_eq!(a.to_csv(), b.to_csv(), "{topology}: series diverged");
        assert!(a.records.last().unwrap().bits > 0, "{topology}: no traffic");
        // workers are normalized out of the config identity, so the two
        // runs are the *same experiment* by hash.
        assert_eq!(
            config_hash(&series_cfg(topology, 1)),
            config_hash(&series_cfg(topology, 8)),
            "{topology}: config identity depends on workers"
        );
    }
}

#[test]
fn topology_switch_series_bit_identical_across_worker_counts() {
    let mk = |workers: usize| ExperimentConfig {
        topology_schedule: "switch:ring,torus:60".into(),
        ..series_cfg("ring", workers)
    };
    let a = run_config(&mk(1), false);
    let b = run_config(&mk(8), false);
    assert_eq!(a.to_csv(), b.to_csv(), "switch series diverged");
    assert!(a.records.last().unwrap().bits > 0);
}

#[test]
fn chaos_run_bit_identical_across_worker_counts_with_sparse_mixing() {
    // Crash/rejoin + partition + corruption exercise `effective_mixing`
    // (sparse row filtering) and the CSR staleness table; the whole
    // composition must stay invariant under the pool's interleaving.
    let run = |workers: usize| {
        let n = 8;
        let d = 16;
        let mixing = uniform_neighbor(&Topology::new(TopologyKind::Ring, n, 0));
        let mut algo = SparqSgd::new(
            SparqConfig {
                mixing,
                compressor: Box::new(SignTopK::new(4)),
                trigger: EventTrigger::new(ThresholdSchedule::Zero),
                lr: LrSchedule::InverseTime { a: 50.0, b: 2.0 },
                sync: SyncSchedule::EveryH(1),
                gamma: None,
                momentum: 0.0,
                seed: 7,
            },
            d,
        );
        algo.set_fault_plan(
            FaultPlan::parse("crash:1:5:20+partition:10:30:0-3|4-7+corrupt:0.1", 7).unwrap(),
        );
        algo.set_workers(workers);
        let mut prob = QuadraticProblem::new(d, n, 0.5, 2.0, 0.05, 1.0, 3);
        let mut bus = Bus::new(n);
        for t in 0..40 {
            algo.step(t, &mut prob, &mut bus);
        }
        let params: Vec<Vec<f32>> = (0..n).map(|i| algo.params(i).to_vec()).collect();
        (params, bus.total_bits, bus.node_bits.clone(), algo.fault_counters())
    };
    let (p1, b1, nb1, c1) = run(1);
    let (p8, b8, nb8, c8) = run(8);
    assert_eq!(p1, p8, "chaos params diverged across worker counts");
    assert_eq!(b1, b8);
    assert_eq!(nb1, nb8);
    assert_eq!(c1, c8);
    // the plan engaged — this is a chaos run, not a quiet one
    assert_eq!(c1.crashes, 1);
    assert!(c1.resyncs > 0);
    assert!(c1.corrupt_discards > 0);
}

// ---------------------------------------------------------------------
// Scale: O(|E|) storage and a real n = 4096 construction + solve
// ---------------------------------------------------------------------

#[test]
fn n4096_construction_and_spectral_solve_run_in_edge_space() {
    for (kind, degree) in [(TopologyKind::Ring, 2), (TopologyKind::RandomRegular(4), 4)] {
        let t = Topology::new(kind, 4096, 11);
        let mm = uniform_neighbor(&t);
        // Storage is Σ_i deg(i) = 2|E| off-diagonal weights — no n² table.
        assert_eq!(mm.stored_weights(), 2 * t.edge_count());
        assert_eq!(mm.stored_weights(), 4096 * degree);
        mm.validate().unwrap();
        // The iterative solver handles n = 4096 (dense Jacobi would be
        // an O(n³) non-starter here) and returns a sane connected-graph
        // spectrum.
        let s = SpectralInfo::compute(&mm);
        assert!((s.lambda1 - 1.0).abs() < 1e-6, "{kind:?}: λ₁={}", s.lambda1);
        assert!(s.delta > 0.0 && s.delta <= 1.0, "{kind:?}: δ={} out of range", s.delta);
        assert!(s.beta > 0.0 && s.beta <= 2.0 + 1e-9, "{kind:?}: β={}", s.beta);
    }
    // Expander beats ring by orders of magnitude — the footnote-5 claim
    // the scale-out exists to measure. (10× not 100×: Lanczos Ritz
    // values sit inside the spectrum, so the ring's tiny true
    // δ ≈ 7.9e-7 is reported conservatively large.)
    let ring_t = Topology::new(TopologyKind::Ring, 4096, 11);
    let reg_t = Topology::new(TopologyKind::RandomRegular(4), 4096, 11);
    let ring = SpectralInfo::compute(&uniform_neighbor(&ring_t));
    let reg = SpectralInfo::compute(&uniform_neighbor(&reg_t));
    assert!(reg.delta > 10.0 * ring.delta, "expander δ {} !≫ ring δ {}", reg.delta, ring.delta);
}
