//! Theorem-level convergence behaviour on the known-optimum quadratic,
//! plus the SPARQ ≡ CHOCO degenerate-case equivalence.
//!
//! These are the paper's *claims* as executable checks:
//! * Theorem 1 / Remark 2 — O(1/nT) decay of the suboptimality and the
//!   distributed 1/n variance gain;
//! * Remark 1 — H, c₀, ω, δ only perturb higher-order terms (larger values
//!   still converge, with bounded degradation at fixed T);
//! * Remark 4 — at equal transmitted bits SPARQ beats CHOCO.

use sparq::comm::Bus;
use sparq::compress::{SignTopK, TopK};
use sparq::coordinator::{ChocoSgd, DecentralizedAlgo, DecentralizedEngine, SparqConfig, SparqSgd};
use sparq::experiments::rates;
use sparq::graph::{uniform_neighbor, Topology, TopologyKind};
use sparq::problems::QuadraticProblem;
use sparq::schedule::{LrSchedule, SyncSchedule};
use sparq::trigger::{EventTrigger, ThresholdSchedule};

#[test]
fn suboptimality_decays_roughly_inverse_in_t() {
    // Theorem 1 dominant term O(1/nT): quadrupling T should cut the gap
    // by ≳ 2 (allowing stochastic slack and higher-order terms).
    let pts = rates::t_sweep(8, &[500, 2000, 8000], 1);
    assert!(
        pts[1].final_gap < pts[0].final_gap / 1.8,
        "T=500: {:.4}, T=2000: {:.4}",
        pts[0].final_gap,
        pts[1].final_gap
    );
    assert!(
        pts[2].final_gap < pts[1].final_gap / 1.8,
        "T=2000: {:.4}, T=8000: {:.4}",
        pts[1].final_gap,
        pts[2].final_gap
    );
}

#[test]
fn more_nodes_reduce_variance_term() {
    // Remark 2: the 1/n factor. Same per-node noise, same T; the final
    // gap should shrink with n (not necessarily by exactly n — consensus
    // error grows with ring size — but the trend must be there).
    let pts = rates::n_sweep(&[2, 16], 4000, 7);
    assert!(
        pts[1].final_gap < pts[0].final_gap,
        "n=2: {:.5}, n=16: {:.5}",
        pts[0].final_gap,
        pts[1].final_gap
    );
}

#[test]
fn local_steps_trade_accuracy_for_bits() {
    // Remark 1(ii): increasing H saves communication but only perturbs
    // higher-order terms — at equal T the H=10 run transmits ~10x fewer
    // bits yet still converges to a comparable gap.
    let h1 = rates::run_point(8, 32, 1, 0.0, 0.25, TopologyKind::Ring, 4000, 3);
    let h10 = rates::run_point(8, 32, 10, 0.0, 0.25, TopologyKind::Ring, 4000, 3);
    assert!(h10.total_bits * 8 < h1.total_bits);
    // both actually converged; H=10 pays only a bounded accuracy penalty
    assert!(h1.final_gap < 0.01, "h1 {}", h1.final_gap);
    assert!(h10.final_gap < 0.05, "h10 {}", h10.final_gap);
}

#[test]
fn smaller_omega_still_converges() {
    // Remark 1(i): heavier compression (smaller ω) moves only the
    // higher-order terms.
    let heavy = rates::run_point_topk(8, 64, 5, 0.05, 6000, 4);
    let light = rates::run_point_topk(8, 64, 5, 0.5, 6000, 4);
    assert!(heavy.omega < light.omega);
    assert!(light.final_gap < 0.05, "light {:.4}", light.final_gap);
    assert!(heavy.final_gap < 0.10, "heavy {:.4}", heavy.final_gap);
}

#[test]
fn better_connectivity_helps_consensus() {
    // Remark 1(iv): larger spectral gap ⇒ faster consensus at equal T.
    let ring = rates::run_point(16, 32, 5, 1.0, 0.25, TopologyKind::Ring, 1500, 5);
    let complete = rates::run_point(16, 32, 5, 1.0, 0.25, TopologyKind::Complete, 1500, 5);
    assert!(complete.delta > ring.delta);
    assert!(complete.final_gap <= ring.final_gap * 1.5 + 1e-3);
}

fn mk_sparq(
    trigger: ThresholdSchedule,
    h: u64,
    seed: u64,
    d: usize,
    n: usize,
) -> (DecentralizedEngine, QuadraticProblem, Bus) {
    let topo = Topology::new(TopologyKind::Ring, n, 0);
    let cfg = SparqConfig {
        mixing: uniform_neighbor(&topo),
        compressor: Box::new(SignTopK::new(d / 4)),
        trigger: EventTrigger::new(trigger),
        lr: LrSchedule::InverseTime { a: 60.0, b: 2.0 },
        sync: SyncSchedule::EveryH(h),
        gamma: None,
        momentum: 0.0,
        seed,
    };
    let algo = SparqSgd::new(cfg, d);
    let prob = QuadraticProblem::new(d, n, 0.5, 2.0, 0.05, 1.0, seed ^ 0xABC);
    (algo, prob, Bus::new(n))
}

#[test]
fn sparq_degenerates_to_choco_exactly() {
    // SPARQ with c_t = 0 and H = 1 must reproduce CHOCO-SGD *bit for bit*
    // given the same seeds (the trigger always fires for nonzero drift;
    // both transmit every round).
    let d = 20;
    let n = 6;
    let (mut sparq, mut prob_a, mut bus_a) = mk_sparq(ThresholdSchedule::Zero, 1, 9, d, n);

    let topo = Topology::new(TopologyKind::Ring, n, 0);
    let mut choco = ChocoSgd::new(
        uniform_neighbor(&topo),
        Box::new(SignTopK::new(d / 4)),
        LrSchedule::InverseTime { a: 60.0, b: 2.0 },
        0.0,
        d,
        9,
    );
    let mut prob_b = QuadraticProblem::new(d, n, 0.5, 2.0, 0.05, 1.0, 9 ^ 0xABC);
    let mut bus_b = Bus::new(n);

    for t in 0..400 {
        sparq.step(t, &mut prob_a, &mut bus_a);
        choco.step(t, &mut prob_b, &mut bus_b);
        for i in 0..n {
            assert_eq!(
                sparq.params(i),
                choco.params(i),
                "trajectories diverged at t={t}, node {i}"
            );
        }
    }
    assert_eq!(bus_a.total_bits, bus_b.total_bits);
    assert_eq!(bus_a.total_messages, bus_b.total_messages);
}

#[test]
fn event_trigger_saves_bits_at_matched_accuracy() {
    // Remark 4, measured: SPARQ with an aggressive trigger reaches the
    // same final accuracy band while transmitting fewer bits than the
    // trigger-free run.
    let (mut no_trig, mut prob_a, mut bus_a) = mk_sparq(ThresholdSchedule::Zero, 5, 11, 32, 8);
    let (mut trig, mut prob_b, mut bus_b) = mk_sparq(
        ThresholdSchedule::Poly { c0: 5.0, eps: 0.5 },
        5,
        11,
        32,
        8,
    );
    for t in 0..6000 {
        no_trig.step(t, &mut prob_a, &mut bus_a);
        trig.step(t, &mut prob_b, &mut bus_b);
    }
    let gap_a = prob_a.suboptimality(&no_trig.x_bar());
    let gap_b = prob_b.suboptimality(&trig.x_bar());
    assert!(
        bus_b.total_bits < bus_a.total_bits,
        "trigger run used {} bits vs {} without",
        bus_b.total_bits,
        bus_a.total_bits
    );
    assert!(gap_b < gap_a * 5.0 + 0.01, "gap {gap_b} vs {gap_a}");
    // the trigger run actually skipped broadcasts
    assert!(trig.total_fired < trig.total_checks);
}

#[test]
fn momentum_variant_converges() {
    // The Section 5.2 configuration (momentum 0.9).
    let topo = Topology::new(TopologyKind::Ring, 8, 0);
    let cfg = SparqConfig {
        mixing: uniform_neighbor(&topo),
        compressor: Box::new(TopK::new(8)),
        trigger: EventTrigger::new(ThresholdSchedule::Constant(2.0)),
        lr: LrSchedule::Constant(0.01),
        sync: SyncSchedule::EveryH(5),
        gamma: None,
        momentum: 0.9,
        seed: 13,
    };
    let mut algo = SparqSgd::new(cfg, 32);
    let mut prob = QuadraticProblem::new(32, 8, 0.5, 2.0, 0.05, 1.0, 14);
    let mut bus = Bus::new(8);
    for t in 0..3000 {
        algo.step(t, &mut prob, &mut bus);
    }
    let gap = prob.suboptimality(&algo.x_bar());
    assert!(gap < 0.25, "momentum run gap {gap}");
}

#[test]
fn theorem2_constant_lr_nonconvex_style_run() {
    // Theorem 2 setting: fixed η = √(n/T); the objective must come down
    // substantially over the horizon.
    let n = 8usize;
    let t_total = 4000u64;
    let topo = Topology::new(TopologyKind::Ring, n, 0);
    let cfg = SparqConfig {
        mixing: uniform_neighbor(&topo),
        compressor: Box::new(SignTopK::new(8)),
        trigger: EventTrigger::new(ThresholdSchedule::Constant(1.0)),
        lr: LrSchedule::theorem2(n, t_total),
        sync: SyncSchedule::EveryH(5),
        gamma: None,
        momentum: 0.0,
        seed: 15,
    };
    let mut algo = SparqSgd::new(cfg, 32);
    let mut prob = QuadraticProblem::new(32, n, 0.5, 2.0, 0.05, 1.0, 16);
    let mut bus = Bus::new(n);
    let g0 = prob.suboptimality(&algo.x_bar());
    for t in 0..t_total {
        algo.step(t, &mut prob, &mut bus);
    }
    let g1 = prob.suboptimality(&algo.x_bar());
    assert!(g1 < g0 * 0.2, "{g0} -> {g1}");
}
