//! Golden-file test for `sparq sweep report`: a committed miniature
//! `results.jsonl` + series fixture must reproduce the Remark-4 savings
//! table and the four Fig-1 CSV panels **byte-for-byte**, including the
//! PR-3 "inf"/"NaN" string encodings (the fixture's diverged run
//! carries `"loss": "inf"` records that must survive the load → render
//! round-trip verbatim).
//!
//! The fixture lives in `rust/tests/fixtures/sweep_report/`:
//! `results.jsonl`, `series/<id>.jsonl`, and `expected/` holding the
//! blessed outputs. If a formatting change is intentional, regenerate
//! the expected files from the new output and commit both.

use std::path::{Path, PathBuf};

use sparq::sweep::report::{self, TargetMetric};

fn fixture_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("rust/tests/fixtures/sweep_report")
}

#[test]
fn golden_savings_table_is_byte_identical() {
    let fixture = fixture_dir();
    let runs = report::load(&fixture).expect("fixture loads");
    assert_eq!(runs.len(), 3, "fixture has three runs");
    // The early-stopped run carries its truncation metadata.
    let stop = runs[0].truncated.as_ref().expect("run 1 is truncated");
    assert_eq!((stop.t, stop.reason.as_str()), (40, "target_error"));
    // The diverged run's non-finite records loaded as real inf/NaN.
    assert!(runs[2].series.records[0].loss.is_infinite());
    assert!(runs[2].series.records[2].loss.is_nan());

    let table = report::savings_table(&runs, TargetMetric::TestError, 0.15);
    let expected = std::fs::read_to_string(fixture.join("expected/savings.txt"))
        .expect("expected/savings.txt");
    assert_eq!(
        table, expected,
        "savings table drifted from the committed golden file"
    );
}

#[test]
fn golden_csv_panels_are_byte_identical() {
    let fixture = fixture_dir();
    let runs = report::load(&fixture).expect("fixture loads");
    for (name, content) in report::panels_csv(&runs) {
        let expected = std::fs::read_to_string(fixture.join("expected").join(name))
            .unwrap_or_else(|e| panic!("expected/{name}: {e}"));
        assert_eq!(content, expected, "{name} drifted from the committed golden file");
    }
}

#[test]
fn duplicate_result_ids_resolve_to_the_last_record() {
    // Merged result sets stay well-defined: a duplicated id (torn-series
    // re-run) resolves to the later record, deterministically.
    let dir = std::env::temp_dir().join(format!("sparq-report-dup-{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(dir.join("series")).unwrap();
    let rec = |t: u64, err: f64, bits: u64| {
        format!(
            r#"{{"t":{t},"loss":{err},"test_error":{err},"opt_gap":"NaN","bits":{bits},"comm_rounds":{t},"consensus":0.5,"fired":1}}"#
        )
    };
    std::fs::write(
        dir.join("series/dup0000000000001.jsonl"),
        format!("{}\n{}\n", rec(0, 0.9, 0), rec(10, 0.1, 500)),
    )
    .unwrap();
    std::fs::write(
        dir.join("results.jsonl"),
        concat!(
            r#"{"id":"dup0000000000001","label":"first","fired":1,"checks":2}"#,
            "\n",
            r#"{"id":"dup0000000000001","label":"second","fired":2,"checks":2}"#,
            "\n"
        ),
    )
    .unwrap();
    let runs = report::load(&dir).unwrap();
    assert_eq!(runs.len(), 1);
    assert_eq!(runs[0].label, "second");
    assert_eq!(runs[0].fired, 2);
    std::fs::remove_dir_all(&dir).ok();
}
