//! Golden-file test for `sparq sweep report`: a committed miniature
//! `results.jsonl` + series fixture must reproduce the Remark-4 savings
//! table and the four Fig-1 CSV panels **byte-for-byte**, including the
//! PR-3 "inf"/"NaN" string encodings (the fixture's diverged run
//! carries `"loss": "inf"` records that must survive the load → render
//! round-trip verbatim).
//!
//! The fixture lives in `rust/tests/fixtures/sweep_report/`:
//! `results.jsonl`, `series/<id>.jsonl`, and `expected/` holding the
//! blessed outputs. If a formatting change is intentional, regenerate
//! the expected files from the new output and commit both.

use std::path::{Path, PathBuf};

use sparq::sweep::report::{self, TargetMetric};

fn fixture_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("rust/tests/fixtures/sweep_report")
}

#[test]
fn golden_savings_table_is_byte_identical() {
    let fixture = fixture_dir();
    let runs = report::load(&fixture).expect("fixture loads");
    assert_eq!(runs.len(), 3, "fixture has three runs");
    // The early-stopped run carries its truncation metadata.
    let stop = runs[0].truncated.as_ref().expect("run 1 is truncated");
    assert_eq!((stop.t, stop.reason.as_str()), (40, "target_error"));
    // The diverged run's non-finite records loaded as real inf/NaN.
    assert!(runs[2].series.records[0].loss.is_infinite());
    assert!(runs[2].series.records[2].loss.is_nan());

    let table = report::savings_table(&runs, TargetMetric::TestError, 0.15);
    let expected = std::fs::read_to_string(fixture.join("expected/savings.txt"))
        .expect("expected/savings.txt");
    assert_eq!(
        table, expected,
        "savings table drifted from the committed golden file"
    );
}

#[test]
fn golden_csv_panels_are_byte_identical() {
    let fixture = fixture_dir();
    let runs = report::load(&fixture).expect("fixture loads");
    for (name, content) in report::panels_csv(&runs) {
        let expected = std::fs::read_to_string(fixture.join("expected").join(name))
            .unwrap_or_else(|e| panic!("expected/{name}: {e}"));
        assert_eq!(content, expected, "{name} drifted from the committed golden file");
    }
}

#[test]
fn duplicate_result_ids_resolve_to_the_last_record() {
    // Merged result sets stay well-defined: a duplicated id (torn-series
    // re-run) resolves to the later record, deterministically.
    let dir = std::env::temp_dir().join(format!("sparq-report-dup-{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(dir.join("series")).unwrap();
    let rec = |t: u64, err: f64, bits: u64| {
        format!(
            r#"{{"t":{t},"loss":{err},"test_error":{err},"opt_gap":"NaN","bits":{bits},"comm_rounds":{t},"consensus":0.5,"fired":1}}"#
        )
    };
    std::fs::write(
        dir.join("series/dup0000000000001.jsonl"),
        format!("{}\n{}\n", rec(0, 0.9, 0), rec(10, 0.1, 500)),
    )
    .unwrap();
    std::fs::write(
        dir.join("results.jsonl"),
        concat!(
            r#"{"id":"dup0000000000001","label":"first","fired":1,"checks":2}"#,
            "\n",
            r#"{"id":"dup0000000000001","label":"second","fired":2,"checks":2}"#,
            "\n"
        ),
    )
    .unwrap();
    let runs = report::load(&dir).unwrap();
    assert_eq!(runs.len(), 1);
    assert_eq!(runs[0].label, "second");
    assert_eq!(runs[0].fired, 2);
    // records predating the families field group under "sparq"
    assert_eq!(runs[0].family, "sparq");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn corrupt_counter_fields_fail_the_load_with_a_named_error() {
    // Regression: a damaged "fired"/"checks" value used to read as a
    // silent 0 (`unwrap_or(0)`) and render as a 0.0% transmit rate; it
    // must instead fail the load naming the file:line, run, and field.
    let dir = std::env::temp_dir().join(format!("sparq-report-corrupt-{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(dir.join("series")).unwrap();
    let series_line =
        r#"{"t":0,"loss":0.9,"test_error":0.9,"opt_gap":"NaN","bits":0,"comm_rounds":0,"consensus":0.5,"fired":1}"#;
    for id in ["good000000000001", "bad0000000000002"] {
        std::fs::write(
            dir.join("series").join(format!("{id}.jsonl")),
            format!("{series_line}\n"),
        )
        .unwrap();
    }
    let write_results = |bad_counters: &str| {
        let good = r#"{"id":"good000000000001","label":"fine","fired":1,"checks":2}"#;
        let bad = format!(r#"{{"id":"bad0000000000002","label":"broken",{bad_counters}}}"#);
        std::fs::write(dir.join("results.jsonl"), format!("{good}\n{bad}\n")).unwrap();
    };

    // fractional count
    write_results(r#""fired":1.5,"checks":2"#);
    let err = report::load(&dir).expect_err("fractional fired must fail the load");
    for needle in ["results.jsonl:2", "bad0000000000002", "\"fired\""] {
        assert!(err.contains(needle), "error {err:?} should name {needle:?}");
    }

    // negative count
    write_results(r#""fired":1,"checks":-3"#);
    let err = report::load(&dir).expect_err("negative checks must fail the load");
    for needle in ["results.jsonl:2", "bad0000000000002", "\"checks\""] {
        assert!(err.contains(needle), "error {err:?} should name {needle:?}");
    }

    // a *missing* counter is still fine (records predate the key)
    write_results(r#""checks":2"#);
    let runs = report::load(&dir).expect("missing counter keys stay loadable");
    assert_eq!(runs.len(), 2);
    assert_eq!((runs[1].fired, runs[1].checks), (0, 2));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn family_key_round_trips_through_the_report_load() {
    let dir = std::env::temp_dir().join(format!("sparq-report-family-{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(dir.join("series")).unwrap();
    let series_line =
        r#"{"t":0,"loss":0.9,"test_error":0.9,"opt_gap":"NaN","bits":0,"comm_rounds":0,"consensus":0.5,"fired":1}"#;
    for id in ["plain00000000001", "squarm0000000002", "coords0000000003"] {
        std::fs::write(
            dir.join("series").join(format!("{id}.jsonl")),
            format!("{series_line}\n"),
        )
        .unwrap();
    }
    std::fs::write(
        dir.join("results.jsonl"),
        concat!(
            r#"{"id":"plain00000000001","label":"a","fired":1,"checks":2}"#,
            "\n",
            r#"{"id":"squarm0000000002","label":"b","fired":1,"checks":2,"family":"squarm:0.9"}"#,
            "\n",
            r#"{"id":"coords0000000003","label":"c","fired":1,"checks":2,"family":"percoord"}"#,
            "\n"
        ),
    )
    .unwrap();
    let runs = report::load(&dir).unwrap();
    let fams: Vec<&str> = runs.iter().map(|r| r.family.as_str()).collect();
    assert_eq!(fams, ["sparq", "squarm:0.9", "percoord"]);
    // and the family panel groups them under those names
    let table = report::family_table(&runs, TargetMetric::Loss, 1.0);
    for fam in ["sparq", "squarm:0.9", "percoord"] {
        assert!(table.contains(fam), "missing {fam} in:\n{table}");
    }
    std::fs::remove_dir_all(&dir).ok();
}
