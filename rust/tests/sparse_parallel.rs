//! The sparse fast path's correctness contracts (ISSUE 1):
//!
//! * `compress_sparse` densifies to *exactly* the dense `compress` output
//!   for the same RNG stream, for every operator;
//! * wire codec byte lengths match the charged bit accounting
//!   (`encoded_bits` for nominal-k messages, `message_bits` for actual
//!   messages) over a (d, k) sweep;
//! * a SPARQ/CHOCO/vanilla run with `workers = 1` and `workers = 8`
//!   produces bit-identical parameters, fired counts, and bus totals.

use sparq::comm::{wire, Bus};
use sparq::compress::{
    self, Compressor, QsgdOp, QsgdTopK, RandK, SignL1, SignTopK, SparseVec, TopK,
};
use sparq::coordinator::{
    ChocoSgd, DecentralizedAlgo, DecentralizedEngine, SparqConfig, SparqSgd,
    VanillaDecentralized,
};
use sparq::graph::{uniform_neighbor, Topology, TopologyKind};
use sparq::problems::QuadraticProblem;
use sparq::prop_assert;
use sparq::schedule::{LrSchedule, SyncSchedule};
use sparq::trigger::{EventTrigger, ThresholdSchedule};
use sparq::util::prop::{check, Config};
use sparq::util::Rng;

fn all_ops(k: usize) -> Vec<Box<dyn Compressor>> {
    vec![
        Box::new(TopK::new(k)),
        Box::new(SignTopK::new(k)),
        Box::new(SignTopK::paper_accounting(k)),
        Box::new(RandK::new(k)),
        Box::new(SignL1),
        Box::new(QsgdOp::new(16)),
        Box::new(QsgdTopK::new(k, 8)),
        Box::new(compress::Identity),
    ]
}

#[test]
fn prop_compress_sparse_densifies_to_dense_output() {
    check("sparse-equals-dense", Config { cases: 48, seed: 0xA1 }, |g| {
        let d = g.dim(600).max(4);
        let x = g.vec_f32(d, 1.0);
        let k = g.usize_in(1, d);
        let seed = g.usize_in(0, 1_000_000) as u64;
        for op in all_ops(k) {
            // identical RNG streams for the two paths
            let mut rng_dense = Rng::new(seed);
            let mut rng_sparse = Rng::new(seed);
            let dense = op.compress_vec(&x, &mut rng_dense);
            let mut q = SparseVec::new();
            op.compress_sparse(&x, &mut rng_sparse, &mut q);
            prop_assert!(
                q.to_dense(d) == dense,
                "{} d={d} k={k}: sparse densify != dense output",
                op.name()
            );
            // both paths must advance the stream identically
            prop_assert!(
                rng_dense.next_u64() == rng_sparse.next_u64(),
                "{} d={d} k={k}: RNG streams diverged",
                op.name()
            );
            // canonical form: strictly increasing indices, nonzero values
            prop_assert!(
                q.idx.windows(2).all(|w| w[0] < w[1]),
                "{}: indices not strictly increasing",
                op.name()
            );
            prop_assert!(
                q.val.iter().all(|v| *v != 0.0),
                "{}: stored zero value",
                op.name()
            );
        }
        Ok(())
    });
}

#[test]
fn prop_wire_lengths_match_charged_bits() {
    check("wire-bits-exact", Config { cases: 48, seed: 0xB2 }, |g| {
        let d = g.dim(4096).max(8);
        let k = g.usize_in(1, d / 2);
        let x = g.vec_f32(d, 1.0);

        let topk = TopK::new(k);
        let mut q = SparseVec::new();
        topk.compress_sparse(&x, &mut Rng::new(1), &mut q);
        let bytes = wire::encode_topk_sparse(&q, d);
        let charged = topk.message_bits(d, q.nnz());
        prop_assert!(
            (bytes.len() as u64) * 8 >= charged && (bytes.len() as u64) * 8 < charged + 8,
            "topk d={d} k={k}: {} bytes vs {charged} charged bits",
            bytes.len()
        );
        // gaussian draws have no magnitude ties (up to measure zero), so
        // the nominal encoded_bits equals the per-message cost; if a tie
        // ever selects extra coordinates the charge grows accordingly
        prop_assert!(q.nnz() >= k, "topk d={d} k={k}: nnz {}", q.nnz());
        if q.nnz() == k {
            prop_assert!(charged == topk.encoded_bits(d), "topk nominal != actual");
        }
        // sparse encoder is byte-identical to the dense encoder
        prop_assert!(
            bytes == wire::encode_topk(&q.to_dense(d)),
            "topk d={d} k={k}: sparse/dense encoders disagree"
        );

        let st = SignTopK::new(k);
        st.compress_sparse(&x, &mut Rng::new(2), &mut q);
        let bytes = wire::encode_sign_topk_sparse(&q, d);
        let charged = st.message_bits(d, q.nnz());
        prop_assert!(
            (bytes.len() as u64) * 8 >= charged && (bytes.len() as u64) * 8 < charged + 8,
            "sign_topk d={d} k={k}: {} bytes vs {charged} charged bits",
            bytes.len()
        );
        if q.nnz() == k {
            prop_assert!(charged == st.encoded_bits(d), "sign_topk nominal != actual");
        }
        prop_assert!(
            bytes == wire::encode_sign_topk(&q.to_dense(d)),
            "sign_topk d={d} k={k}: sparse/dense encoders disagree"
        );
        Ok(())
    });
}

fn mk_sparq(workers: usize, seed: u64) -> (DecentralizedEngine, QuadraticProblem, Bus) {
    let n = 8;
    let d = 96;
    let topo = Topology::new(TopologyKind::Ring, n, 0);
    let cfg = SparqConfig {
        mixing: uniform_neighbor(&topo),
        compressor: Box::new(SignTopK::new(d / 10)),
        trigger: EventTrigger::new(ThresholdSchedule::Constant(5.0)),
        lr: LrSchedule::InverseTime { a: 60.0, b: 2.0 },
        sync: SyncSchedule::EveryH(2),
        gamma: None,
        momentum: 0.0,
        seed,
    };
    let mut algo = SparqSgd::new(cfg, d);
    algo.set_workers(workers);
    // noisy heterogeneous quadratic: exercises the shared-grad parallel
    // phase (QuadraticProblem supports shared-state evaluation)
    let prob = QuadraticProblem::new(d, n, 0.5, 2.0, 0.05, 1.0, seed ^ 0xFE);
    let bus = Bus::new(n);
    (algo, prob, bus)
}

#[test]
fn sparq_parallel_run_is_bit_identical_to_sequential() {
    let steps = 400u64;
    let (mut seq, mut prob_a, mut bus_a) = mk_sparq(1, 17);
    let (mut par, mut prob_b, mut bus_b) = mk_sparq(8, 17);
    for t in 0..steps {
        seq.step(t, &mut prob_a, &mut bus_a);
        par.step(t, &mut prob_b, &mut bus_b);
    }
    for i in 0..8 {
        assert_eq!(seq.params(i), par.params(i), "node {i} params diverged");
        assert_eq!(seq.xhat(i), par.xhat(i), "node {i} estimates diverged");
    }
    assert_eq!(seq.total_fired, par.total_fired, "fired counts diverged");
    assert_eq!(seq.total_checks, par.total_checks);
    assert_eq!(bus_a.total_bits, bus_b.total_bits, "bus bits diverged");
    assert_eq!(bus_a.total_messages, bus_b.total_messages);
    assert_eq!(bus_a.comm_rounds, bus_b.comm_rounds);
    assert_eq!(bus_a.node_bits, bus_b.node_bits);
    // and the run actually did something
    assert!(seq.total_fired > 0);
    assert!(bus_a.total_bits > 0);
}

#[test]
fn choco_parallel_run_is_bit_identical_to_sequential() {
    let n = 6;
    let d = 48;
    let topo = Topology::new(TopologyKind::Ring, n, 0);
    let mk = |workers: usize| {
        let mut algo = ChocoSgd::new(
            uniform_neighbor(&topo),
            Box::new(TopK::new(6)),
            LrSchedule::InverseTime { a: 50.0, b: 2.0 },
            0.0,
            d,
            23,
        );
        algo.set_workers(workers);
        (algo, QuadraticProblem::new(d, n, 0.5, 2.0, 0.05, 1.0, 29), Bus::new(n))
    };
    let (mut seq, mut prob_a, mut bus_a) = mk(1);
    let (mut par, mut prob_b, mut bus_b) = mk(8);
    for t in 0..300 {
        seq.step(t, &mut prob_a, &mut bus_a);
        par.step(t, &mut prob_b, &mut bus_b);
    }
    for i in 0..n {
        assert_eq!(seq.params(i), par.params(i), "node {i} params diverged");
    }
    assert_eq!(bus_a.total_bits, bus_b.total_bits);
    assert_eq!(bus_a.total_messages, bus_b.total_messages);
}

#[test]
fn vanilla_parallel_run_is_bit_identical_to_sequential() {
    let n = 6;
    let d = 40;
    let topo = Topology::new(TopologyKind::Ring, n, 0);
    let mk = |workers: usize| {
        let mut algo = VanillaDecentralized::new(
            uniform_neighbor(&topo),
            LrSchedule::Constant(0.05),
            0.9, // momentum path included
            d,
            31,
        );
        algo.set_workers(workers);
        (algo, QuadraticProblem::new(d, n, 0.5, 2.0, 0.05, 1.0, 37), Bus::new(n))
    };
    let (mut seq, mut prob_a, mut bus_a) = mk(1);
    let (mut par, mut prob_b, mut bus_b) = mk(8);
    for t in 0..200 {
        seq.step(t, &mut prob_a, &mut bus_a);
        par.step(t, &mut prob_b, &mut bus_b);
    }
    for i in 0..n {
        assert_eq!(seq.params(i), par.params(i), "node {i} params diverged");
        assert_eq!(seq.momentum(i), par.momentum(i), "node {i} momentum diverged");
    }
    assert_eq!(bus_a.total_bits, bus_b.total_bits);
}

#[test]
fn run_config_workers_field_is_deterministic_end_to_end() {
    // Full config → builder → runner path, non-shared-grad source
    // (logreg): the gradient phase falls back to sequential while the
    // compress/consensus phases still fan out — output must be identical.
    use sparq::config::ExperimentConfig;
    use sparq::experiments::run_config;

    let mk = |workers: usize| ExperimentConfig {
        nodes: 6,
        steps: 150,
        eval_every: 50,
        problem: "logreg:16:4:4".into(),
        compressor: "sign_topk:25%".into(),
        trigger: "const:20".into(),
        workers,
        ..Default::default()
    };
    let a = run_config(&mk(1), false);
    let b = run_config(&mk(8), false);
    assert_eq!(a.to_csv(), b.to_csv(), "series diverged across worker counts");
}

#[test]
fn charged_bits_track_actual_message_sizes() {
    // A live SPARQ run charges message_bits of the actual nnz — for
    // gaussian-ish drifts (no magnitude ties) that equals the nominal
    // encoded_bits, so totals are exactly messages × nominal.
    let (mut algo, mut prob, mut bus) = mk_sparq(1, 41);
    for t in 0..100 {
        algo.step(t, &mut prob, &mut bus);
    }
    let nominal = SignTopK::new(96 / 10).encoded_bits(96);
    // ring: degree 2 ⇒ every message charged twice. A magnitude tie can
    // only select *extra* coordinates (nnz > k ⇒ more bits), so actual
    // charges are ≥ nominal and — ties being measure-zero on gaussian-ish
    // drifts — almost always exactly nominal.
    let expected = bus.total_messages * nominal * 2;
    assert!(
        bus.total_bits >= expected && bus.total_bits <= expected + expected / 100,
        "charged {} vs nominal {}",
        bus.total_bits,
        expected
    );
}
