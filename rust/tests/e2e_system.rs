//! End-to-end system tests over the full stack: config → builder →
//! coordinator → problem → metrics, including the PJRT-backed path when
//! artifacts exist.

use sparq::config::{presets, Algo, ExperimentConfig};
use sparq::coordinator::{run, RunOptions};
use sparq::experiments::{build_algo, build_problem, fig1, run_config};
use sparq::metrics::Series;

#[test]
fn convex_preset_scaled_down_learns() {
    // The Section 5.1 preset with a smaller grid so it runs in seconds:
    // n=12 ring, heterogeneous logreg, SignTopK + trigger.
    let mut cfg = presets::convex_sparq(800);
    cfg.nodes = 12;
    cfg.problem = "logreg:48:6:5".into();
    cfg.compressor = "sign_topk:10%".into();
    cfg.trigger = "const:50".into();
    cfg.eval_every = 200;
    let series = run_config(&cfg, false);
    let first = &series.records[0];
    let last = series.records.last().unwrap();
    assert!(
        last.test_error < first.test_error * 0.6,
        "test error {} -> {}",
        first.test_error,
        last.test_error
    );
    assert!(last.bits > 0 && last.comm_rounds > 0);
    // H=5 ⇒ at most steps/5 comm rounds
    assert!(last.comm_rounds <= cfg.steps / 5 + 1);
}

#[test]
fn nonconvex_preset_scaled_down_learns() {
    let mut cfg = presets::nonconvex_sparq(1200, 60);
    cfg.nodes = 8;
    cfg.problem = "mlp:64:24:6:8".into();
    cfg.lr = "warmup:0.05:1:5:60:150,250".into();
    cfg.eval_every = 300;
    let series = run_config(&cfg, false);
    let first = &series.records[0];
    let last = series.records.last().unwrap();
    assert!(
        last.loss < first.loss * 0.8,
        "loss {} -> {}",
        first.loss,
        last.loss
    );
}

#[test]
fn fig1_shape_holds_on_scaled_suite() {
    // The paper's Figure-1b ordering at reduced scale: bits-to-target for
    // SPARQ < CHOCO(SignTopK) < CHOCO(Sign) < vanilla. We assert the two
    // endpoints (SPARQ best, vanilla worst) and that every compressed
    // curve beats vanilla — run-to-run noise can swap adjacent CHOCO
    // variants at this scale.
    let mut suite = fig1::convex_suite(900, 5);
    for (_, cfg) in suite.iter_mut() {
        cfg.nodes = 10;
        cfg.problem = "logreg:32:4:6".into();
        if cfg.compressor == "sign_topk:10" {
            cfg.compressor = "sign_topk:10%".into();
        }
        cfg.trigger = "const:20".into();
        cfg.eval_every = 60;
    }
    let series = fig1::run_suite(suite, false);
    let target = 0.22;
    let bits =
        |s: &Series| s.first_reaching_error(target).map(|r| r.bits);
    let sparq = bits(&series[0]);
    let vanilla = bits(&series[4]);
    let (Some(sparq), Some(vanilla)) = (sparq, vanilla) else {
        panic!(
            "curves did not reach target {target}: sparq {:?}, vanilla {:?}",
            series[0].records.last().map(|r| r.test_error),
            series[4].records.last().map(|r| r.test_error)
        );
    };
    assert!(
        sparq < vanilla,
        "SPARQ bits {sparq} !< vanilla bits {vanilla}"
    );
    for s in &series[1..4] {
        if let Some(b) = bits(s) {
            assert!(b < vanilla, "{}: {b} !< vanilla {vanilla}", s.label);
            assert!(sparq <= b, "SPARQ {sparq} !<= {}: {b}", s.label);
        }
    }
}

#[test]
fn vanilla_and_choco_and_sparq_all_run_via_builder() {
    for algo in [Algo::Sparq, Algo::Choco, Algo::Vanilla] {
        let cfg = ExperimentConfig {
            algo,
            nodes: 5,
            steps: 120,
            eval_every: 60,
            problem: "quadratic:16".into(),
            ..Default::default()
        };
        let mut problem = build_problem(&cfg);
        let d = problem.dim();
        let mut a = build_algo(&cfg, d);
        let series = run(
            a.as_mut(),
            problem.as_mut(),
            &RunOptions {
                steps: cfg.steps,
                eval_every: cfg.eval_every,
                verbose: false,
                workers: 1,
            },
        );
        let last = series.records.last().unwrap();
        assert!(last.opt_gap.is_finite());
        assert!(last.opt_gap < series.records[0].opt_gap);
    }
}

#[test]
fn checkpoint_resume_reproduces_trajectory() {
    // Snapshot at t=100, keep training to t=200; restoring the snapshot
    // into a fresh algorithm (v2 checkpoints carry params, momentum, the
    // estimate bank + consensus accumulator, AND the node RNG streams)
    // and stepping the remaining 100 iterations must land on the
    // uninterrupted trajectory bit for bit.
    use sparq::comm::Bus;
    use sparq::coordinator::checkpoint;

    let cfg = ExperimentConfig {
        nodes: 5,
        steps: 100,
        problem: "quadratic:24".into(),
        momentum: 0.9,
        ..Default::default()
    };
    let mut problem = build_problem(&cfg);
    let mut algo = build_algo(&cfg, problem.dim());
    let mut bus = Bus::new(cfg.nodes);
    for t in 0..100 {
        algo.step(t, problem.as_mut(), &mut bus);
    }
    let ckpt = checkpoint::snapshot(algo.as_ref(), 100, &bus);
    assert_eq!(ckpt.n(), 5);
    assert_eq!(ckpt.dim(), 24);
    assert!(!ckpt.momentum.is_empty(), "momentum run must checkpoint m");
    assert!(!ckpt.xhat.is_empty(), "SPARQ must checkpoint its x̂ bank");
    assert_eq!(ckpt.rng.len(), 5, "per-node RNG streams checkpointed");

    let path = std::env::temp_dir().join(format!("sparq-e2e-ckpt-{}.bin", std::process::id()));
    ckpt.save(&path).expect("save");
    let loaded = sparq::coordinator::Checkpoint::load(&path).expect("load");
    assert_eq!(ckpt, loaded);
    std::fs::remove_file(&path).ok();

    let mut problem2 = build_problem(&cfg);
    let mut algo2 = build_algo(&cfg, 24);
    let mut bus2 = Bus::new(cfg.nodes);
    checkpoint::restore(algo2.as_mut(), &loaded).unwrap();
    checkpoint::restore_bus(&mut bus2, &loaded);
    assert_eq!(bus.total_bits, bus2.total_bits);
    for i in 0..5 {
        assert_eq!(algo.params(i), algo2.params(i), "node {i} params");
        assert_eq!(algo.momentum(i), algo2.momentum(i), "node {i} momentum");
    }
    // continue both to t=200: bit-for-bit the same run
    for t in 100..200 {
        algo.step(t, problem.as_mut(), &mut bus);
        algo2.step(t, problem2.as_mut(), &mut bus2);
    }
    for i in 0..5 {
        assert_eq!(algo.params(i), algo2.params(i), "node {i} diverged after resume");
    }
    assert_eq!(bus.total_bits, bus2.total_bits);
    assert_eq!(bus.node_bits, bus2.node_bits);
    let a = problem.global_loss(&algo.x_bar());
    let b = problem2.global_loss(&algo2.x_bar());
    assert_eq!(a, b);
}

#[test]
fn parallel_suite_matches_sequential() {
    let mk = || {
        let mut suite = fig1::convex_suite(200, 9);
        for (_, cfg) in suite.iter_mut() {
            cfg.nodes = 6;
            cfg.problem = "logreg:16:4:4".into();
            if cfg.compressor.as_str().starts_with("sign_topk:10") {
                cfg.compressor = "sign_topk:25%".into();
            }
            cfg.eval_every = 100;
        }
        suite
    };
    let seq = fig1::run_suite(mk(), false);
    let par = fig1::run_suite_parallel(mk(), 3);
    assert_eq!(seq.len(), par.len());
    for (a, b) in seq.iter().zip(par.iter()) {
        // compare rendered records (opt_gap is NaN here and NaN != NaN)
        assert_eq!(a.to_csv(), b.to_csv(), "{} diverged", a.label);
    }
}

#[test]
fn pjrt_logreg_training_short_run() {
    // Full-stack smoke over the artifact path: a few SPARQ iterations with
    // gradients computed by the PJRT logreg artifact. Skips without
    // artifacts.
    use sparq::data::synthetic::ClassGaussian;
    use sparq::data::by_class_shards;
    use sparq::runtime::{Manifest, PjrtModel, Runtime};
    use sparq::util::Rng;

    let Some(m) = Manifest::load_default() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let rt = match Runtime::new(m) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("skipping: PJRT unavailable: {e}");
            return;
        }
    };
    let n = 4;
    let gen = ClassGaussian::new(784, 10, 1.6, 21);
    let mut rng = Rng::new(22);
    let part = by_class_shards(&gen, n, 40, 2, &mut rng);
    let test = gen.generate(256, &mut rng);
    let mut model = PjrtModel::new(rt, "logreg", part, test).expect("model");

    let cfg = ExperimentConfig {
        nodes: n,
        steps: 60,
        eval_every: 30,
        compressor: "sign_topk:10%".into(),
        trigger: "const:20".into(),
        lr: "invtime:100:2".into(),
        ..Default::default()
    };
    let mut algo = build_algo(&cfg, 7850);
    let series = run(
        algo.as_mut(),
        &mut model,
        &RunOptions {
            steps: cfg.steps,
            eval_every: cfg.eval_every,
            verbose: false,
            workers: 1,
        },
    );
    let first = &series.records[0];
    let last = series.records.last().unwrap();
    assert!(
        last.loss < first.loss,
        "PJRT-backed training did not reduce loss: {} -> {}",
        first.loss,
        last.loss
    );
}
