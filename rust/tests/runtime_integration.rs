//! Cross-layer equivalence: the Rust L3 operators must agree with the AOT
//! HLO artifacts (L2 JAX graphs embedding the L1 Pallas kernels) executed
//! through PJRT. These tests are the contract that lets the experiment hot
//! path use the native implementations interchangeably.
//!
//! All tests skip (pass vacuously, with a note) when `artifacts/` has not
//! been built — run `make artifacts` first for full coverage.

use sparq::compress::{Compressor, QsgdOp, SignTopK};
use sparq::linalg::vecops::dist2;
use sparq::problems::GradientSource;
use sparq::runtime::client::Input;
use sparq::runtime::{Manifest, Runtime};
use sparq::util::Rng;

fn runtime() -> Option<Runtime> {
    match Manifest::load_default() {
        Some(m) => match Runtime::new(m) {
            Ok(rt) => Some(rt),
            Err(e) => {
                eprintln!("PJRT unavailable: {e}");
                None
            }
        },
        None => {
            eprintln!("skipping: artifacts not built (run `make artifacts`)");
            None
        }
    }
}

fn randvec(seed: u64, d: usize, sigma: f32) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    let mut v = vec![0.0f32; d];
    rng.fill_normal(&mut v, sigma);
    v
}

fn assert_close(a: &[f32], b: &[f32], tol: f32, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
        let denom = 1.0 + x.abs().max(y.abs());
        assert!(
            (x - y).abs() / denom <= tol,
            "{what}[{i}]: {x} vs {y}"
        );
    }
}

#[test]
fn manifest_loads_and_all_artifacts_compile() {
    let Some(mut rt) = runtime() else { return };
    let names: Vec<String> = rt.manifest.artifacts.keys().cloned().collect();
    assert!(names.len() >= 10, "expected full artifact set, got {names:?}");
    // Compile the cheap ones eagerly (lm_grad is compiled in its own test).
    for name in names {
        if name.starts_with("lm_") || name.starts_with("mlp_") {
            continue;
        }
        rt.executor(&name).unwrap_or_else(|e| panic!("{name}: {e}"));
    }
}

#[test]
fn sign_topk_artifact_matches_rust_operator() {
    let Some(mut rt) = runtime() else { return };
    for seed in [1u64, 2, 3] {
        let x = randvec(seed, 4096, 1.0);
        let exe = rt.executor("compress_sign_topk_d4096_k409").unwrap();
        let q_art = &exe.run(&[Input::F32(&x)]).unwrap()[0];
        let mut rng = Rng::new(0);
        let q_rust = SignTopK::new(409).compress_vec(&x, &mut rng);
        assert_close(q_art, &q_rust, 2e-5, "sign_topk");
    }
}

#[test]
fn sign_topk_artifact_paper_dims() {
    let Some(mut rt) = runtime() else { return };
    let x = randvec(9, 7850, 0.5);
    let exe = rt.executor("compress_sign_topk_d7850_k10").unwrap();
    let q_art = &exe.run(&[Input::F32(&x)]).unwrap()[0];
    let mut rng = Rng::new(0);
    let q_rust = SignTopK::new(10).compress_vec(&x, &mut rng);
    assert_close(q_art, &q_rust, 2e-5, "sign_topk_7850");
    assert_eq!(q_art.iter().filter(|v| **v != 0.0).count(), 10);
}

#[test]
fn gossip_artifact_matches_rust_consensus_math() {
    let Some(mut rt) = runtime() else { return };
    let (n, d) = (8usize, 4096usize);
    let x = randvec(11, n * d, 1.0);
    let xhat = randvec(12, n * d, 1.0);
    // ring mixing matrix, row-major
    let mut w = vec![0.0f32; n * n];
    for i in 0..n {
        w[i * n + i] = 1.0 / 3.0;
        w[i * n + (i + 1) % n] = 1.0 / 3.0;
        w[i * n + (i + n - 1) % n] = 1.0 / 3.0;
    }
    let gamma = 0.4f32;
    let exe = rt.executor("gossip_n8_d4096").unwrap();
    let out = &exe
        .run(&[
            Input::F32(&x),
            Input::F32(&xhat),
            Input::F32(&w),
            Input::ScalarF32(gamma),
        ])
        .unwrap()[0];
    // rust reference: x + gamma * (W xhat - xhat), row-major (n, d)
    let mut expect = x.clone();
    for i in 0..n {
        for j in 0..n {
            let wij = w[i * n + j];
            if wij == 0.0 {
                continue;
            }
            for k in 0..d {
                expect[i * d + k] += gamma * wij * xhat[j * d + k];
            }
        }
        for k in 0..d {
            expect[i * d + k] -= gamma * xhat[i * d + k];
        }
    }
    assert_close(out, &expect, 5e-5, "gossip");
}

#[test]
fn sgd_momentum_artifact_matches_reference() {
    let Some(mut rt) = runtime() else { return };
    let d = 4096;
    let x = randvec(21, d, 1.0);
    let g = randvec(22, d, 1.0);
    let m = randvec(23, d, 0.5);
    let (eta, mu) = (0.05f32, 0.9f32);
    let exe = rt.executor("sgd_momentum_d4096").unwrap();
    let out = exe
        .run(&[
            Input::F32(&x),
            Input::F32(&g),
            Input::F32(&m),
            Input::ScalarF32(eta),
            Input::ScalarF32(mu),
        ])
        .unwrap();
    let m_new: Vec<f32> = m.iter().zip(g.iter()).map(|(mi, gi)| mu * mi + gi).collect();
    let x_new: Vec<f32> = x
        .iter()
        .zip(m_new.iter())
        .map(|(xi, mi)| xi - eta * mi)
        .collect();
    assert_close(&out[0], &x_new, 1e-5, "sgd x'");
    assert_close(&out[1], &m_new, 1e-5, "sgd m'");
}

#[test]
fn qsgd_artifact_matches_rust_with_shared_uniforms() {
    let Some(mut rt) = runtime() else { return };
    let d = 4096;
    let x = randvec(31, d, 1.0);
    let mut rng = Rng::new(32);
    let u: Vec<f32> = (0..d).map(|_| rng.f32()).collect();
    let exe = rt.executor("qsgd_d4096_s16").unwrap();
    let out = &exe.run(&[Input::F32(&x), Input::F32(&u)]).unwrap()[0];
    let mut q_rust = vec![0.0f32; d];
    QsgdOp::new(16).compress_with_uniforms(&x, &u, &mut q_rust);
    assert_close(out, &q_rust, 1e-4, "qsgd");
}

#[test]
fn trigger_artifact_matches_rust_rule() {
    let Some(mut rt) = runtime() else { return };
    let d = 4096;
    let x_half = randvec(41, d, 0.1);
    let xhat = randvec(42, d, 0.1);
    let drift = dist2(&x_half, &xhat);
    let eta = 0.01f32;
    // threshold just above and below the actual drift
    for (c, expect) in [
        ((drift * 0.5 / (eta as f64 * eta as f64)) as f32, true),
        ((drift * 2.0 / (eta as f64 * eta as f64)) as f32, false),
    ] {
        let exe = rt.executor("trigger_check_d4096").unwrap();
        let out = &exe
            .run(&[
                Input::F32(&x_half),
                Input::F32(&xhat),
                Input::ScalarF32(c),
                Input::ScalarF32(eta),
            ])
            .unwrap()[0];
        assert_eq!(out[0] != 0.0, expect, "c={c}");
    }
}

#[test]
fn logreg_artifact_matches_native_problem() {
    use sparq::data::synthetic::ClassGaussian;
    use sparq::data::by_class_shards;
    use sparq::problems::LogRegProblem;

    let Some(mut rt) = runtime() else { return };

    // Same batch through both paths.
    let gen = ClassGaussian::new(784, 10, 1.6, 5);
    let mut rng = Rng::new(6);
    let part = by_class_shards(&gen, 2, 30, 2, &mut rng);
    let test = gen.generate(64, &mut rng);
    let mut native = LogRegProblem::new(part.clone(), test, 5, 1e-4);
    let d = native.dim();

    let params = randvec(51, d, 0.05);
    let mut rng_a = Rng::new(99);
    let mut g_native = vec![0.0f32; d];
    let loss_native = native.grad(0, &params, &mut rng_a, &mut g_native);

    // replay the same batch for the artifact path
    let mut rng_b = Rng::new(99);
    let (xs, ys) = part.batch(0, 5, &mut rng_b);
    let exe = rt.executor("logreg_grad").unwrap();
    let out = exe
        .run(&[Input::F32(&params), Input::F32(&xs), Input::I32(&ys)])
        .unwrap();
    let loss_art = out[0][0] as f64;
    assert!(
        (loss_native - loss_art).abs() < 1e-3 * (1.0 + loss_native.abs()),
        "loss native {loss_native} vs artifact {loss_art}"
    );
    assert_close(&out[1], &g_native, 1e-3, "logreg grad");
}
