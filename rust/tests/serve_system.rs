//! `sparq serve` — the ISSUE-8 acceptance tests, driving real daemons
//! over real sockets:
//!
//! * in-process over TCP: corrupt/garbage frames are rejected with a
//!   structured error and the connection loop survives whenever framing
//!   sync does; two concurrent subscribers receive **identical** event
//!   streams; admission rejects an invalid spec with exactly the text
//!   `sparq check` prints for it;
//! * child processes over a Unix socket: one daemon executes two
//!   tenants' submissions under one worker budget with every per-run
//!   series **bit-identical** (`f64::to_bits`) to a serial
//!   single-process sweep;
//! * a fault-killed daemon leaves claims, checkpoints, and its durable
//!   job files behind; a restarted daemon re-admits the job, takes the
//!   stale claims over, resumes from the checkpoints, and records every
//!   run exactly once — series still bit-identical to serial.

use std::path::{Path, PathBuf};
use std::process::{Command, Output, Stdio};
use std::time::Duration;

use sparq::comm::wire::{frame, FRAME_OVERHEAD};
use sparq::config::ExperimentConfig;
use sparq::metrics::Series;
use sparq::serve::{spawn, Client, Response, ServeConfig, MAX_FRAME_BYTES};
use sparq::sweep::{run_spec, SweepOptions, SweepSpec};
use sparq::util::json::Json;

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("sparq-serve-{tag}-{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn assert_series_bits_eq(a: &Series, b: &Series, what: &str) {
    assert_eq!(a.records.len(), b.records.len(), "{what}: record counts");
    for (ra, rb) in a.records.iter().zip(b.records.iter()) {
        assert_eq!(ra.t, rb.t, "{what}: t");
        assert_eq!(ra.loss.to_bits(), rb.loss.to_bits(), "{what}: loss at t={}", ra.t);
        assert_eq!(
            ra.test_error.to_bits(),
            rb.test_error.to_bits(),
            "{what}: test_error at t={}",
            ra.t
        );
        assert_eq!(ra.opt_gap.to_bits(), rb.opt_gap.to_bits(), "{what}: opt_gap at t={}", ra.t);
        assert_eq!(ra.bits, rb.bits, "{what}: bits at t={}", ra.t);
        assert_eq!(ra.comm_rounds, rb.comm_rounds, "{what}: rounds at t={}", ra.t);
        assert_eq!(
            ra.consensus.to_bits(),
            rb.consensus.to_bits(),
            "{what}: consensus at t={}",
            ra.t
        );
        assert_eq!(ra.fired, rb.fired, "{what}: fired at t={}", ra.t);
    }
}

fn base_cfg() -> ExperimentConfig {
    ExperimentConfig {
        name: "dist-grid".into(),
        nodes: 5,
        steps: 160,
        eval_every: 40,
        problem: "quadratic:24".into(),
        compressor: "sign_topk:25%".into(),
        trigger: "const:20".into(),
        h: sparq::config::SyncSpec::every(2),
        ..Default::default()
    }
}

/// Seed-axis grid over [`base_cfg`], named `name`.
fn grid(name: &str, seeds: &[u64]) -> SweepSpec {
    SweepSpec::new(name).base(&base_cfg()).axis_u64("seed", seeds)
}

/// A grid small enough for in-process tests (4 runs × 40 steps).
fn quick_spec() -> SweepSpec {
    let base = ExperimentConfig {
        name: "serve-quick".into(),
        nodes: 4,
        steps: 40,
        eval_every: 20,
        problem: "quadratic:16".into(),
        compressor: "sign_topk:25%".into(),
        trigger: "const:20".into(),
        h: sparq::config::SyncSpec::every(2),
        ..Default::default()
    };
    SweepSpec::new("serve-quick").base(&base).axis_u64("seed", &[1, 2, 3, 4])
}

/// Serial single-process reference: id → series.
fn serial_reference(spec: &SweepSpec) -> Vec<(String, Series)> {
    let report = run_spec(
        spec,
        &SweepOptions {
            workers: 2,
            ..Default::default()
        },
    )
    .expect("serial sweep");
    report
        .outcomes
        .into_iter()
        .map(|o| (o.id, o.series))
        .collect()
}

fn spawn_daemon(out: &Path, workers: usize) -> sparq::serve::ServerHandle {
    spawn(ServeConfig {
        socket: "127.0.0.1:0".into(),
        out: out.to_path_buf(),
        workers,
        poll_ms: 20,
        ..Default::default()
    })
    .expect("spawn daemon")
}

fn connect(addr: &str) -> Client {
    Client::connect_retry(addr, Duration::from_secs(10)).expect("connect")
}

fn claim_files(out: &Path) -> Vec<String> {
    let mut v = Vec::new();
    if let Ok(entries) = std::fs::read_dir(out.join("claims")) {
        for e in entries.flatten() {
            let name = e.file_name().to_string_lossy().to_string();
            if name.ends_with(".claim") {
                v.push(name.trim_end_matches(".claim").to_string());
            }
        }
    }
    v.sort();
    v
}

fn result_ids(out: &Path) -> Vec<String> {
    let Ok(text) = std::fs::read_to_string(out.join("results.jsonl")) else {
        return Vec::new();
    };
    text.lines()
        .filter(|l| !l.trim().is_empty())
        .map(|l| {
            let j = Json::parse(l).expect("valid record line");
            j.get("id").and_then(|v| v.as_str().map(str::to_string)).expect("record id")
        })
        .collect()
}

fn assert_exactly_once(out: &Path, reference: &[(String, Series)], what: &str) {
    let mut ids = result_ids(out);
    ids.sort();
    let mut expected: Vec<String> = reference.iter().map(|(id, _)| id.clone()).collect();
    expected.sort();
    assert_eq!(ids, expected, "{what}: every run id recorded exactly once");
    assert!(claim_files(out).is_empty(), "{what}: all claims released");
    for (id, serial) in reference {
        let path = out.join("series").join(format!("{id}.jsonl"));
        let stored = Series::read_jsonl(&path, "stored").expect("stored series");
        assert_series_bits_eq(serial, &stored, &format!("{what}: run {id} vs serial"));
    }
}

// ---------------------------------------------------------------------
// In-process protocol tests (TCP, portable)
// ---------------------------------------------------------------------

#[test]
fn corrupt_and_garbage_frames_get_structured_errors_and_the_daemon_survives() {
    let dir = tmp_dir("protocol");
    let handle = spawn_daemon(&dir.join("out"), 1);
    let addr = handle.addr().to_string();

    let mut c = connect(&addr);
    assert_eq!(c.ping().expect("ping"), sparq::version());

    // Bit-flipped payload: CRC mismatch with sane framing. The daemon
    // answers with a structured error and keeps serving the connection.
    let mut wire = frame(br#"{"type":"ping"}"#);
    wire[FRAME_OVERHEAD] ^= 0x10;
    c.send_raw(&wire).unwrap();
    match c.read_response().expect("error response") {
        Response::Error { error } => {
            assert!(error.contains("bad frame"), "unexpected error: {error}")
        }
        other => panic!("expected Error, got {other:?}"),
    }
    assert_eq!(c.ping().expect("ping after corrupt frame"), sparq::version());

    // Valid frame, non-JSON payload — still nonfatal.
    c.send_payload(b"not json at all").unwrap();
    match c.read_response().expect("error response") {
        Response::Error { error } => {
            assert!(error.contains("bad request"), "unexpected error: {error}")
        }
        other => panic!("expected Error, got {other:?}"),
    }

    // Valid JSON, unknown request type — still nonfatal.
    c.send_payload(br#"{"type":"frobnicate"}"#).unwrap();
    match c.read_response().expect("error response") {
        Response::Error { error } => {
            assert!(error.contains("bad request"), "unexpected error: {error}")
        }
        other => panic!("expected Error, got {other:?}"),
    }
    assert_eq!(c.ping().expect("ping after garbage"), sparq::version());

    // An insane length prefix desynchronizes the stream: the daemon
    // reports the error, then drops this connection — but not others.
    let mut c2 = connect(&addr);
    let mut header = ((MAX_FRAME_BYTES + 1) as u32).to_le_bytes().to_vec();
    header.extend_from_slice(&[0u8; 4]);
    c2.send_raw(&header).unwrap();
    match c2.read_response().expect("error response") {
        Response::Error { error } => {
            assert!(error.contains("bad frame"), "unexpected error: {error}")
        }
        other => panic!("expected Error, got {other:?}"),
    }
    assert!(c2.read_response().is_err(), "fatal desync must close the connection");
    assert_eq!(c.ping().expect("other connections unaffected"), sparq::version());

    drop(c);
    drop(c2);
    handle.stop().expect("clean shutdown");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn admission_rejects_invalid_specs_with_sparq_check_text() {
    // A spec that parses and expands but fails `resolve()`: a torus
    // needs a perfect-square node count.
    let bad = SweepSpec::new("bad-grid").base(&ExperimentConfig {
        name: "bad-torus".into(),
        nodes: 5,
        topology: "torus".into(),
        steps: 40,
        eval_every: 20,
        problem: "quadratic:16".into(),
        ..Default::default()
    });
    let dir = tmp_dir("admission");
    let spec_path = dir.join("bad.json");
    std::fs::write(&spec_path, bad.to_json().to_string_pretty()).unwrap();

    // `sparq check` rejects it and prints one line: "{path}: {error}".
    let check = Command::new(env!("CARGO_BIN_EXE_sparq"))
        .args(["check", "--spec"])
        .arg(&spec_path)
        .stdout(Stdio::piped())
        .stderr(Stdio::piped())
        .output()
        .expect("sparq check");
    assert!(!check.status.success(), "check must reject the spec");
    let stderr = String::from_utf8_lossy(&check.stderr);
    let line = stderr.lines().next().expect("one diagnostic line");
    let prefix = format!("{}: ", spec_path.display());
    let check_text = line
        .strip_prefix(&prefix)
        .unwrap_or_else(|| panic!("diagnostic should start with {prefix:?}: {line}"));

    // The daemon rejects the same spec with the identical diagnostic.
    let handle = spawn_daemon(&dir.join("out"), 1);
    let mut c = connect(handle.addr());
    let err = c.submit(&bad.to_json(), 0).expect_err("admission must reject");
    assert_eq!(err, check_text, "admission text matches `sparq check`");

    // Nothing was queued or persisted for the rejected job.
    let (jobs, _) = c.status().expect("status");
    assert!(jobs.is_empty(), "rejected job must not appear in the queue");
    assert_eq!(
        std::fs::read_dir(dir.join("out").join("jobs")).unwrap().count(),
        0,
        "rejected job must not be persisted"
    );

    drop(c);
    handle.stop().expect("clean shutdown");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn concurrent_subscribers_see_identical_event_streams() {
    let spec = quick_spec();
    let runs = spec.len();
    let dir = tmp_dir("subscribers");
    let out = dir.join("out");
    let handle = spawn_daemon(&out, 2);
    let addr = handle.addr().to_string();

    // Two subscribers attach before any work exists; each collects the
    // full stream until the job's completion record.
    let watcher = |addr: String| {
        std::thread::spawn(move || -> Vec<(u64, String)> {
            let client = connect(&addr);
            let mut seen = Vec::new();
            client
                .watch(true, &mut |seq, event| {
                    seen.push((seq, event.to_string()));
                    event.get("kind").and_then(Json::as_str) != Some("job-complete")
                })
                .expect("watch stream");
            seen
        })
    };
    let w1 = watcher(addr.clone());
    let w2 = watcher(addr.clone());

    let mut c = connect(&addr);
    let (job, accepted) = c.submit(&spec.to_json(), 0).expect("submit");
    assert_eq!(accepted, runs);

    let s1 = w1.join().expect("subscriber 1");
    let s2 = w2.join().expect("subscriber 2");
    assert_eq!(s1, s2, "subscribers must observe the identical sequence");

    // The stream is complete and causally ordered: accept, start/finish
    // per run, then the job record; sequence numbers are gapless.
    for (i, (seq, _)) in s1.iter().enumerate() {
        assert_eq!(*seq, i as u64, "gapless sequence numbers");
    }
    let kind_count = |kind: &str| {
        s1.iter()
            .filter(|(_, e)| {
                Json::parse(e).unwrap().get("kind").and_then(Json::as_str) == Some(kind)
            })
            .count()
    };
    assert_eq!(kind_count("job-accepted"), 1);
    assert_eq!(kind_count("started"), runs);
    assert_eq!(kind_count("finished"), runs);
    assert_eq!(kind_count("job-complete"), 1);
    assert_eq!(
        s1.last().map(|(_, e)| {
            let j = Json::parse(e).unwrap();
            (
                j.get("kind").and_then(Json::as_str).unwrap_or_default().to_string(),
                j.get("job").and_then(Json::as_str).unwrap_or_default().to_string(),
            )
        }),
        Some(("job-complete".to_string(), job.clone())),
        "stream ends at the job's completion record"
    );

    // Resubmitting the finished job settles instantly from the recorded
    // results — accepted again, but nothing re-executes.
    let (job2, accepted2) = c.submit(&spec.to_json(), 0).expect("resubmit");
    assert_eq!(job2, job, "same spec content is the same job");
    assert_eq!(accepted2, runs);
    let (jobs, claims) = c.status().expect("status");
    assert_eq!(jobs.len(), 1);
    assert_eq!(jobs[0].state, "complete");
    assert_eq!((jobs[0].done, jobs[0].failed, jobs[0].total), (runs, 0, runs));
    assert!(claims.is_empty());
    assert_eq!(result_ids(&out).len(), runs, "resubmission must not re-record runs");

    drop(c);
    handle.stop().expect("clean shutdown");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn evicted_event_prefix_fails_watch_from_start_with_truncation_error() {
    let spec = quick_spec();
    let dir = tmp_dir("ring");
    // A tiny ring: the quick grid publishes 10 lifecycle events
    // (accept, 4× started/finished, complete), so a 4-event ring is
    // guaranteed to evict the prefix.
    let handle = spawn(ServeConfig {
        socket: "127.0.0.1:0".into(),
        out: dir.join("out"),
        workers: 2,
        poll_ms: 20,
        event_capacity: 4,
        ..Default::default()
    })
    .expect("spawn daemon");
    let addr = handle.addr().to_string();

    let mut c = connect(&addr);
    let (_job, accepted) = c.submit(&spec.to_json(), 0).expect("submit");
    assert_eq!(accepted, spec.len());
    loop {
        let (jobs, _) = c.status().expect("status");
        if jobs.first().is_some_and(|j| j.state == "complete") {
            break;
        }
        std::thread::sleep(Duration::from_millis(20));
    }

    // Replaying from seq 0 is impossible now: the daemon must say so
    // up front — a structured truncation error, zero events delivered —
    // never a stream with a silent hole.
    let mut seen = Vec::new();
    let err = connect(&addr)
        .watch(true, &mut |seq, _| {
            seen.push(seq);
            true
        })
        .expect_err("watch --from-start over an evicted prefix must fail");
    assert!(
        err.contains("log truncated at seq"),
        "unexpected error: {err}"
    );
    assert!(seen.is_empty(), "no events before the truncation error: {seen:?}");

    // A tail subscriber is unaffected: it attaches at the live cursor
    // and follows new events (the resubmitted job settles instantly
    // from recorded results, publishing accept + complete only).
    let tail = connect(&addr);
    let tailer = std::thread::spawn(move || -> Vec<String> {
        let mut kinds = Vec::new();
        tail.watch(false, &mut |_seq, e| {
            let kind = e.get("kind").and_then(Json::as_str).unwrap_or_default().to_string();
            kinds.push(kind.clone());
            kind != "job-complete"
        })
        .expect("tail watch");
        kinds
    });
    std::thread::sleep(Duration::from_millis(300));
    let (_job2, _) = c.submit(&spec.to_json(), 0).expect("resubmit");
    let kinds = tailer.join().expect("tail subscriber");
    assert_eq!(
        kinds,
        ["job-accepted", "job-complete"],
        "tail stream follows post-eviction events"
    );

    drop(c);
    handle.stop().expect("clean shutdown");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn cancel_releases_queued_runs_and_survives_a_restart() {
    let dir = tmp_dir("cancel");
    let out = dir.join("out");
    // One worker: job A (higher priority) occupies it, so job B's runs
    // are still queued when the cancel lands.
    let handle = spawn_daemon(&out, 1);
    let addr = handle.addr().to_string();
    let mut c = connect(&addr);

    // A long enough horizon that A's first run alone outlasts the
    // submit + cancel round trips below (and A outranks B on priority,
    // so the worker never reaches B's slots regardless).
    let mut busy = base_cfg();
    busy.name = "cancel-busy".into();
    busy.steps = 2000;
    busy.eval_every = 500;
    let spec_a = SweepSpec::new("cancel-busy").base(&busy).axis_u64("seed", &[1, 2, 3, 4]);
    let spec_b = grid("cancel-victim", &[5, 6, 7, 8]);
    let (job_a, _) = c.submit(&spec_a.to_json(), 10).expect("submit A");
    let (job_b, runs_b) = c.submit(&spec_b.to_json(), 0).expect("submit B");

    // Unknown jobs are a structured error, not a silent no-op.
    let err = c.cancel("job-ffffffffffffffff").expect_err("unknown job");
    assert!(err.contains("no such job"), "unexpected error: {err}");

    let released = c.cancel(&job_b).expect("cancel B");
    assert_eq!(released, runs_b, "every queued run of B is released");
    let err = c.cancel(&job_b).expect_err("second cancel");
    assert!(err.contains("already settled"), "unexpected error: {err}");

    // Status: B reads as cancelled; its runs never execute.
    let (jobs, _) = c.status().expect("status");
    let b = jobs.iter().find(|j| j.job == job_b).expect("job B row");
    assert_eq!(b.state, "cancelled");
    assert_eq!((b.cancelled, b.done, b.failed), (runs_b, 0, 0));

    // The persisted job file is marked, so the cancel outlives daemons.
    let marked = std::fs::read_dir(out.join("jobs"))
        .unwrap()
        .flatten()
        .filter(|e| e.file_name().to_string_lossy().contains(&job_b))
        .count();
    assert_eq!(marked, 1, "B's job file survives, marked cancelled");

    // The event stream carries the cancellation in causal order.
    let mut kinds = Vec::new();
    connect(&addr)
        .watch(true, &mut |_seq, e| {
            if e.get("job").and_then(Json::as_str) == Some(job_b.as_str()) {
                let kind =
                    e.get("kind").and_then(Json::as_str).unwrap_or_default().to_string();
                kinds.push(kind.clone());
                return kind != "job-complete";
            }
            true
        })
        .expect("watch");
    assert_eq!(
        kinds,
        ["job-accepted", "job-cancelled", "job-complete"],
        "B's stream: accepted, cancelled, complete"
    );

    // A still runs to completion — cancellation is per-job.
    loop {
        let (jobs, _) = c.status().expect("status");
        if jobs.iter().any(|j| j.job == job_a && j.state == "complete") {
            break;
        }
        std::thread::sleep(Duration::from_millis(20));
    }
    drop(c);
    handle.stop().expect("clean shutdown");

    // A restarted daemon re-admits A (settled from records) but skips
    // the cancelled B entirely.
    let handle2 = spawn_daemon(&out, 1);
    let mut c2 = connect(handle2.addr());
    let (jobs, _) = c2.status().expect("status after restart");
    assert!(
        jobs.iter().any(|j| j.job == job_a && j.state == "complete"),
        "A re-admits settled: {jobs:?}"
    );
    assert!(
        !jobs.iter().any(|j| j.job == job_b),
        "cancelled B must not re-queue: {jobs:?}"
    );
    drop(c2);
    handle2.stop().expect("clean shutdown");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn jobs_retain_collects_only_the_oldest_settled_job_files() {
    let dir = tmp_dir("retain");
    let out = dir.join("out");
    let handle = spawn(ServeConfig {
        socket: "127.0.0.1:0".into(),
        out: out.clone(),
        workers: 2,
        poll_ms: 20,
        jobs_retain: 1,
        ..Default::default()
    })
    .expect("spawn daemon");
    let mut c = connect(handle.addr());

    // Three distinct single-seed jobs, completed in sequence.
    let mut job_files = Vec::new();
    for (i, seed) in [11u64, 22, 33].iter().enumerate() {
        let spec = grid(&format!("retain-{i}"), &[*seed]);
        let (job, _) = c.submit(&spec.to_json(), 0).expect("submit");
        loop {
            let (jobs, _) = c.status().expect("status");
            if jobs.iter().any(|j| j.job == job && j.state == "complete") {
                break;
            }
            std::thread::sleep(Duration::from_millis(20));
        }
        job_files.push(job);
    }

    // Only the newest settled job file survives --jobs-retain 1.
    let names: Vec<String> = std::fs::read_dir(out.join("jobs"))
        .unwrap()
        .flatten()
        .map(|e| e.file_name().to_string_lossy().to_string())
        .collect();
    assert_eq!(names.len(), 1, "retention keeps exactly one file: {names:?}");
    assert!(
        names[0].contains(&job_files[2]),
        "the survivor is the newest job: {names:?}"
    );

    drop(c);
    handle.stop().expect("clean shutdown");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn auth_token_gates_every_connection_first_frame() {
    let dir = tmp_dir("auth");
    let handle = spawn(ServeConfig {
        socket: "127.0.0.1:0".into(),
        out: dir.join("out"),
        workers: 1,
        poll_ms: 20,
        auth_token: Some("sesame".into()),
        ..Default::default()
    })
    .expect("spawn daemon");
    let addr = handle.addr().to_string();

    // Unauthenticated first request: structured error, then the daemon
    // closes the connection.
    let mut c = connect(&addr);
    let err = c.ping().expect_err("ping without auth");
    assert!(err.contains("authentication required"), "unexpected error: {err}");
    assert!(c.ping().is_err(), "connection closed after the auth error");

    // Wrong token: structured error + close.
    let mut c = connect(&addr);
    let err = c.auth("open").expect_err("wrong token");
    assert!(err.contains("token mismatch"), "unexpected error: {err}");

    // Right token as the first frame unlocks the whole session.
    let mut c = connect(&addr);
    c.auth("sesame").expect("auth");
    assert_eq!(c.ping().expect("ping after auth"), sparq::version());
    let (jobs, _) = c.status().expect("status after auth");
    assert!(jobs.is_empty());

    drop(c);
    handle.stop().expect("clean shutdown");

    // Without a configured token, auth is an accepted no-op — clients
    // may send it unconditionally.
    let open = spawn_daemon(&dir.join("out2"), 1);
    let mut c = connect(open.addr());
    c.auth("anything").expect("auth against an open daemon");
    assert_eq!(c.ping().expect("ping"), sparq::version());
    drop(c);
    open.stop().expect("clean shutdown");
    std::fs::remove_dir_all(&dir).ok();
}

// ---------------------------------------------------------------------
// Child-process end-to-end tests (Unix socket)
// ---------------------------------------------------------------------

#[cfg(unix)]
fn sparq_serve(sock: &Path, out: &Path, extra: &[&str]) -> Command {
    let mut cmd = Command::new(env!("CARGO_BIN_EXE_sparq"));
    cmd.arg("serve")
        .arg("--socket")
        .arg(sock)
        .arg("--out")
        .arg(out)
        .args(["--poll-ms", "50"])
        .args(extra)
        .stdout(Stdio::piped())
        .stderr(Stdio::piped());
    cmd
}

/// `sparq submit`; returns the accepted job id and the child output.
#[cfg(unix)]
fn sparq_submit(sock: &Path, spec_path: &Path, wait: bool) -> (String, Output) {
    let mut cmd = Command::new(env!("CARGO_BIN_EXE_sparq"));
    cmd.arg("submit").arg("--socket").arg(sock).arg("--spec").arg(spec_path);
    if wait {
        cmd.arg("--wait");
    }
    let out = cmd
        .stdout(Stdio::piped())
        .stderr(Stdio::piped())
        .output()
        .expect("sparq submit");
    assert!(
        out.status.success(),
        "submit failed:\n{}\n{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    let stdout = String::from_utf8_lossy(&out.stdout).to_string();
    let job = stdout
        .lines()
        .find_map(|l| l.strip_prefix("accepted "))
        .and_then(|rest| rest.split(':').next())
        .unwrap_or_else(|| panic!("no acceptance line in:\n{stdout}"))
        .to_string();
    (job, out)
}

#[cfg(unix)]
fn write_spec(spec: &SweepSpec, path: &Path) -> PathBuf {
    std::fs::write(path, spec.to_json().to_string_pretty()).unwrap();
    path.to_path_buf()
}

#[cfg(unix)]
#[test]
fn daemon_runs_two_tenants_under_one_budget_bit_identical_to_serial() {
    // Two tenants split the 8-seed grid; the serial reference runs it
    // whole. Run identity is the config hash, so the split is invisible
    // to the per-run comparisons.
    let reference = serial_reference(&grid("dist-grid", &[1, 2, 3, 4, 5, 6, 7, 8]));
    assert_eq!(reference.len(), 8);

    let dir = tmp_dir("tenants");
    let out = dir.join("out");
    let sock = dir.join("d.sock");
    let spec_a = write_spec(&grid("tenant-a", &[1, 2, 3, 4]), &dir.join("a.json"));
    let spec_b = write_spec(&grid("tenant-b", &[5, 6, 7, 8]), &dir.join("b.json"));

    let daemon = sparq_serve(&sock, &out, &["--workers", "2", "--lease-secs", "30"])
        .spawn()
        .expect("spawn daemon");

    let (job_a, sub_a) = sparq_submit(&sock, &spec_a, true);
    let (job_b, sub_b) = sparq_submit(&sock, &spec_b, true);
    assert_ne!(job_a, job_b, "different grids are different jobs");
    for (tag, sub) in [("a", &sub_a), ("b", &sub_b)] {
        let stdout = String::from_utf8_lossy(&sub.stdout);
        assert!(
            stdout.contains("job-complete"),
            "tenant {tag} wait must end at job-complete:\n{stdout}"
        );
    }

    // The live status endpoint agrees: both jobs complete, no claims.
    let status = Command::new(env!("CARGO_BIN_EXE_sparq"))
        .arg("status")
        .arg("--socket")
        .arg(&sock)
        .stdout(Stdio::piped())
        .stderr(Stdio::piped())
        .output()
        .expect("sparq status");
    assert!(status.status.success());
    let status_out = String::from_utf8_lossy(&status.stdout).to_string();
    assert!(
        status_out.matches("complete").count() >= 2 && status_out.contains("no held claims"),
        "status must show both jobs complete:\n{status_out}"
    );

    let shutdown = Command::new(env!("CARGO_BIN_EXE_sparq"))
        .arg("shutdown")
        .arg("--socket")
        .arg(&sock)
        .output()
        .expect("sparq shutdown");
    assert!(shutdown.status.success());
    let o = daemon.wait_with_output().expect("daemon exit");
    assert!(
        o.status.success(),
        "daemon failed:\n{}\n{}",
        String::from_utf8_lossy(&o.stdout),
        String::from_utf8_lossy(&o.stderr)
    );
    assert!(!sock.exists(), "graceful shutdown unlinks the socket");

    assert_exactly_once(&out, &reference, "two tenants");
    std::fs::remove_dir_all(&dir).ok();
}

#[cfg(unix)]
#[test]
fn killed_daemon_restart_completes_the_job_exactly_once_bit_for_bit() {
    let spec = grid("dist-grid", &[1, 2, 3, 4, 5, 6, 7, 8]);
    let reference = serial_reference(&spec);

    let dir = tmp_dir("restart");
    let out = dir.join("out");
    let sock = dir.join("d.sock");
    let spec_path = write_spec(&spec, &dir.join("spec.json"));

    // Daemon 1 "crashes": fault injection aborts its first claimed run
    // at t = 80 (after the t = 40 and t = 80 checkpoints), leaving the
    // claim, the checkpoints, and the durable job file in place.
    let daemon1 = sparq_serve(
        &sock,
        &out,
        &[
            "--workers",
            "1",
            "--lease-secs",
            "1",
            "--checkpoint-every",
            "40",
            "--fault-abort-at",
            "80",
        ],
    )
    .spawn()
    .expect("spawn daemon 1");
    let (job, _) = sparq_submit(&sock, &spec_path, false);
    let o1 = daemon1.wait_with_output().expect("daemon 1 exit");
    assert!(!o1.status.success(), "fault-injected daemon must exit nonzero");
    assert!(
        String::from_utf8_lossy(&o1.stderr).contains("fault injection"),
        "stderr: {}",
        String::from_utf8_lossy(&o1.stderr)
    );
    let abandoned = claim_files(&out);
    assert_eq!(abandoned.len(), 1, "exactly one abandoned claim: {abandoned:?}");
    let victim = abandoned[0].clone();
    assert!(
        out.join("ckpt").join(format!("{victim}.ckpt")).exists(),
        "mid-run checkpoint left behind for takeover"
    );
    assert!(result_ids(&out).is_empty(), "no result recorded for the aborted run");
    assert_eq!(
        std::fs::read_dir(out.join("jobs")).unwrap().count(),
        1,
        "the job file survives the crash"
    );

    // Let the lease expire, then restart over the same directory. The
    // new daemon re-admits the persisted job on its own — no resubmit —
    // takes the stale claim over, and resumes from the checkpoint.
    std::thread::sleep(Duration::from_millis(1200));
    let daemon2 = sparq_serve(
        &sock,
        &out,
        &[
            "--workers",
            "2",
            "--lease-secs",
            "1",
            "--lease-margin-secs",
            "0",
            "--checkpoint-every",
            "40",
        ],
    )
    .spawn()
    .expect("spawn daemon 2");

    // `sparq watch --job` replays from the start of the new daemon's
    // stream and exits at the job's completion record.
    let watch = Command::new(env!("CARGO_BIN_EXE_sparq"))
        .arg("watch")
        .arg("--socket")
        .arg(&sock)
        .args(["--job", &job])
        .stdout(Stdio::piped())
        .stderr(Stdio::piped())
        .output()
        .expect("sparq watch");
    assert!(
        watch.status.success(),
        "watch failed:\n{}",
        String::from_utf8_lossy(&watch.stderr)
    );
    assert!(
        String::from_utf8_lossy(&watch.stdout).contains("job-complete"),
        "watch must end at job-complete:\n{}",
        String::from_utf8_lossy(&watch.stdout)
    );

    let shutdown = Command::new(env!("CARGO_BIN_EXE_sparq"))
        .arg("shutdown")
        .arg("--socket")
        .arg(&sock)
        .output()
        .expect("sparq shutdown");
    assert!(shutdown.status.success());
    let o2 = daemon2.wait_with_output().expect("daemon 2 exit");
    assert!(
        o2.status.success(),
        "restarted daemon failed:\n{}\n{}",
        String::from_utf8_lossy(&o2.stdout),
        String::from_utf8_lossy(&o2.stderr)
    );
    let stdout2 = String::from_utf8_lossy(&o2.stdout);
    assert!(
        stdout2.contains("resume") && stdout2.contains("from t="),
        "takeover must resume from the checkpoint, not restart:\n{stdout2}"
    );
    assert!(
        !out.join("ckpt").join(format!("{victim}.ckpt")).exists(),
        "completed run clears the inherited checkpoint"
    );

    assert_exactly_once(&out, &reference, "restart takeover");
    std::fs::remove_dir_all(&dir).ok();
}
