//! Cluster-runtime system tests (ISSUE 10 satellite): a real
//! multi-process UDS cluster must be bit-for-bit identical to the
//! in-process engine, and a fault-plan crash window must really
//! `SIGKILL` a node process and rejoin it with the same resync
//! accounting the in-process engine charges.
//!
//! Each node here is a genuine OS process spawned from the `sparq`
//! binary (`env!("CARGO_BIN_EXE_sparq")` — `current_exe()` inside a
//! test is the test harness, not the CLI). Identity is pinned three
//! ways at once: our own in-process reference below, the launcher's
//! replica cross-check, and its `verify` re-run.

#![cfg(unix)]

use std::path::{Path, PathBuf};

use sparq::cluster::{run_cluster, series_hash, ClusterOptions, KillEvent};
use sparq::config::ExperimentConfig;
use sparq::experiments::fig1;
use sparq::run::Run;

fn tmp_dir(tag: &str) -> PathBuf {
    let pid = std::process::id();
    // Keep the path short: UDS socket paths live under it and have a
    // ~104-byte OS limit.
    let d = std::env::temp_dir().join(format!("sparq-cluster-{tag}-{pid}"));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).expect("mkdir");
    d
}

/// One SPARQ point of the Fig 1a grid, shrunk the same way fig1's own
/// mini suite shrinks it: tiny problem, low trigger threshold (so
/// broadcasts actually travel), coarse eval cadence.
fn point(nodes: usize, steps: u64, seed: u64) -> ExperimentConfig {
    let mut cfg = fig1::convex_point(nodes, steps, seed);
    cfg.problem = "logreg:24:4:8".into();
    cfg.trigger = "const:10".into();
    cfg.eval_every = 20;
    cfg
}

fn cluster_opts(cfg: ExperimentConfig, dir: &Path) -> ClusterOptions {
    ClusterOptions {
        cfg,
        dir: dir.to_path_buf(),
        exe: PathBuf::from(env!("CARGO_BIN_EXE_sparq")),
        checkpoint_every: 0, // crash boundaries only
        verify: true,
        verbose: false,
        timeout_secs: 300.0,
    }
}

#[test]
fn four_node_uds_cluster_is_bit_identical_to_the_in_process_engine() {
    let cfg = point(4, 120, 7);
    let resolved = cfg.resolve().expect("resolve");
    let mut reference = Run::from_resolved(&resolved, None, cfg.workers.max(1));
    reference.run_to_end().expect("in-process reference");
    let expect_hash = series_hash(reference.series());
    let expect_bits = reference.bus().total_bits;
    let (expect_fired, expect_checks) = reference.fired_stats();
    assert!(
        expect_fired > 0,
        "the config must fire triggers or nothing crosses the wire"
    );

    let dir = tmp_dir("lockstep");
    let report = run_cluster(&cluster_opts(cfg, &dir)).expect("cluster run");

    assert_eq!(report.nodes, 4);
    assert_eq!(report.series_hash, expect_hash);
    assert_eq!(report.total_bits, expect_bits);
    assert_eq!((report.fired, report.checks), (expect_fired, expect_checks));
    // The launcher's own in-process verification agreed too.
    assert_eq!(report.verified.as_deref(), Some(expect_hash.as_str()));
    // Lockstep: nobody died, nothing resynced, and every receive came
    // off the wire — zero fallbacks proves the identity was not
    // achieved by silently degrading to local computation.
    assert!(report.kills.is_empty());
    assert_eq!((report.crashes, report.resyncs), (0, 0));
    assert_eq!(report.wire_mismatches, 0);
    assert_eq!(report.wire_fallbacks, 0);
    // Artifacts: the cross-checked report and rank 0's series.
    assert!(dir.join("report.json").exists());
    assert!(dir.join("out").join("series.jsonl").exists());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn a_crash_window_really_kills_and_rejoins_with_in_process_accounting() {
    let mut cfg = point(4, 100, 11);
    cfg.fault = "crash:1:40:60".parse().expect("fault spec");
    let resolved = cfg.resolve().expect("resolve");
    let mut reference = Run::from_resolved(&resolved, None, cfg.workers.max(1));
    reference.run_to_end().expect("in-process reference");
    let expect_hash = series_hash(reference.series());
    let fault = reference.snapshot().fault;
    assert!(fault.crashes >= 1, "the window must register in-process");

    let dir = tmp_dir("crash");
    let report = run_cluster(&cluster_opts(cfg, &dir)).expect("cluster run");

    // The launcher delivered exactly one real SIGKILL, at the window
    // boundary, and respawned the rank to rejoin at t = up.
    assert_eq!(
        report.kills,
        vec![KillEvent {
            rank: 1,
            t_down: 40,
            t_up: 60,
        }]
    );
    // Bit-identity survives the kill: the respawn restored the crash
    // boundary checkpoint and replayed the window muted, so the series
    // and the resync charges match the in-process engine exactly.
    assert_eq!(report.series_hash, expect_hash);
    assert_eq!(report.crashes, fault.crashes);
    assert_eq!(report.resyncs, fault.resyncs);
    assert!(report.verified.is_some());
    assert_eq!(report.wire_mismatches, 0);
    // The kill marker was consumed and the crash-boundary checkpoint
    // (cadence 0: the only one anyone writes) belongs to rank 1.
    assert!(!dir.join("kill").join("node-1.json").exists());
    assert!(dir.join("ckpt").join("node-1.ckpt").exists());
    assert!(!dir.join("ckpt").join("node-0.ckpt").exists());
    let _ = std::fs::remove_dir_all(&dir);
}
