//! Golden fixtures for the typed-config redesign.
//!
//! The redesign's hard compatibility promise: replacing string fields
//! with typed specs changes **nothing observable** about config
//! serialization — `to_json()` emits byte-identical JSON, so
//! `config_hash` (which hashes that text) assigns every pre-redesign
//! run the same id, and existing sweep `results.jsonl`/series files
//! keep resuming. The literals below are exactly what the string-field
//! implementation produced (sorted keys, the in-tree writer's number
//! formatting); the fnv helper is the same FNV-1a the hash uses.
//!
//! Also pinned here: `ConfigError` rendering for representative invalid
//! compositions (the CLI surface), and that every committed
//! `examples/specs/*.json` expands and resolves.

use sparq::config::{presets, ExperimentConfig};
use sparq::experiments::fig1;
use sparq::sweep::{config_hash, SweepSpec};
use sparq::util::json::Json;

/// FNV-1a 64 over a string — must mirror `sweep::spec::config_hash`.
fn fnv64(text: &str) -> String {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in text.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    format!("{h:016x}")
}

/// The pre-redesign serialization of the Fig-1 convex base, with the
/// per-variant (algo, compressor, name) substituted. Field order is the
/// serializer's sorted-key order.
fn convex_canonical(algo: &str, compressor: &str, name: &str) -> String {
    format!(
        r#"{{"algo":"{algo}","compressor":"{compressor}","eval_every":25,"gamma":0,"h":5,"link":"none","lr":"invtime:100:1","momentum":0,"name":"{name}","nodes":60,"problem":"logreg:784:10:5","seed":42,"steps":3000,"topology":"ring","topology_schedule":"static","trigger":"const:5000","workers":1}}"#
    )
}

#[test]
fn default_config_serializes_to_the_string_era_bytes() {
    let expected = r#"{"algo":"sparq","compressor":"sign_topk:10%","eval_every":50,"gamma":0,"h":5,"link":"none","lr":"invtime:100:1","momentum":0,"name":"default","nodes":8,"problem":"quadratic:64","seed":42,"steps":1000,"topology":"ring","topology_schedule":"static","trigger":"const:100","workers":1}"#;
    assert_eq!(ExperimentConfig::default().to_json().to_string(), expected);
}

#[test]
fn preset_configs_serialize_to_the_string_era_bytes() {
    assert_eq!(
        presets::convex_sparq(3000).to_json().to_string(),
        convex_canonical("sparq", "sign_topk:10", "fig1-convex-sparq")
    );
    // The non-convex preset pins float spellings ("2.0"/"1.0" in the
    // piecewise trigger, momentum 0.9) and the warmup lr string.
    let expected = r#"{"algo":"sparq","compressor":"sign_topk:10%","eval_every":50,"gamma":0,"h":5,"link":"none","lr":"warmup:0.05:5:5:100:150,250","momentum":0.9,"name":"fig1-nonconvex-sparq","nodes":8,"problem":"mlp:3072:128:10:32","seed":42,"steps":2000,"topology":"ring","topology_schedule":"static","trigger":"piecewise:2.0:1.0:10:60:100","workers":1}"#;
    assert_eq!(
        presets::nonconvex_sparq(2000, 100).to_json().to_string(),
        expected
    );
}

#[test]
fn config_hash_of_the_five_driver_specs_is_unchanged() {
    // config_hash normalizes name → "" and workers → 1 before hashing
    // the canonical text; both were already in the literals' form for
    // workers, so only the name blanks.
    let variants = [
        ("sparq", "sign_topk:10", "fig1-convex-sparq"),
        ("choco", "sign", "fig1-convex-choco-sign"),
        ("choco", "topk:10", "fig1-convex-choco-topk"),
        ("choco", "sign_topk:10", "fig1-convex-choco-signtopk"),
        ("vanilla", "identity", "fig1-convex-vanilla"),
    ];
    let runs = fig1::convex_suite(3000, 42);
    assert_eq!(runs.len(), variants.len());
    for ((algo, compressor, name), (_, cfg)) in variants.iter().zip(runs.iter()) {
        assert_eq!(cfg.name, *name);
        // The expanded config serializes to the string-era bytes...
        assert_eq!(
            cfg.to_json().to_string(),
            convex_canonical(algo, compressor, name),
            "{name}: serialization drifted"
        );
        // ...and hashes to the string-era id.
        let normalized = convex_canonical(algo, compressor, "");
        assert_eq!(
            config_hash(cfg),
            fnv64(&normalized),
            "{name}: config_hash drifted"
        );
    }
}

#[test]
fn every_committed_spec_file_expands_and_resolves() {
    let mut checked = 0;
    for entry in std::fs::read_dir("examples/specs").expect("examples/specs/") {
        let path = entry.expect("dir entry").path();
        if path.extension().and_then(|e| e.to_str()) != Some("json") {
            continue;
        }
        let spec = SweepSpec::from_file(path.to_str().unwrap())
            .unwrap_or_else(|e| panic!("{}: {e}", path.display()));
        let runs = spec
            .expand()
            .unwrap_or_else(|e| panic!("{}: {e}", path.display()));
        assert!(!runs.is_empty(), "{}: empty grid", path.display());
        for (label, cfg) in &runs {
            cfg.resolve().unwrap_or_else(|e| {
                panic!("{} run {label:?}: {e}", path.display())
            });
            // Round-tripping the expanded config through its own JSON is
            // the identity — spec files and in-code configs agree.
            let back = ExperimentConfig::from_json(&cfg.to_json()).unwrap();
            assert_eq!(&back, cfg);
        }
        checked += 1;
    }
    assert!(checked >= 3, "expected the three committed spec files, saw {checked}");
}

#[test]
fn fig1_convex_spec_file_matches_the_in_code_driver() {
    // The committed JSON form of the Fig-1 convex grid expands to the
    // exact configs (and therefore result ids) of the in-code driver —
    // a sweep started from the file resumes one started from the code.
    let from_file = SweepSpec::from_file("examples/specs/fig1_convex.json")
        .expect("fig1_convex.json")
        .expand()
        .expect("expands");
    let from_code = fig1::convex_suite(3000, 42);
    assert_eq!(from_file.len(), from_code.len());
    for ((fl, fc), (cl, cc)) in from_file.iter().zip(from_code.iter()) {
        assert_eq!(fl, cl, "labels diverge");
        assert_eq!(config_hash(fc), config_hash(cc), "{fl}: ids diverge");
        assert_eq!(fc, cc, "{fl}: configs diverge");
    }
}

#[test]
fn config_error_messages_are_pinned() {
    // Snapshot the structured errors for representative invalid
    // compositions — field, value, reason, suggestion, exactly as the
    // CLI prints them.
    let parse_err = |body: &str| -> String {
        ExperimentConfig::from_json(&Json::parse(body).unwrap())
            .expect_err("must reject")
            .to_string()
    };
    assert_eq!(
        parse_err(r#"{"trigger": "poly:2:1.5"}"#),
        "invalid trigger \"poly:2:1.5\": trigger eps must lie in the open interval (0, 1) \
         so that c_t = c0·t^(1-eps) is o(t) (Theorem 1), got 1.5"
    );
    assert_eq!(
        parse_err(r#"{"compressor": "topk:0"}"#),
        "invalid compressor \"topk:0\": k must be >= 1"
    );
    assert_eq!(
        parse_err(r#"{"compressor": "gzip"}"#),
        "invalid compressor \"gzip\": unknown operator (try: identity, sign, topk:K, \
         randk:K, qsgd:S, sign_topk:K[:paper], or qsgd_topk:K:S (K may be %-suffixed))"
    );
    assert_eq!(
        parse_err(r#"{"lr": "const:fast"}"#),
        "invalid lr \"const:fast\": lr eta \"fast\" is not a number"
    );
    assert_eq!(
        parse_err(r#"{"link": "drop:2"}"#),
        "invalid link \"drop:2\": drop probability must be in [0, 1), got 2"
    );
    assert_eq!(
        parse_err(r#"{"h": "explicit:5,3"}"#),
        "invalid h \"explicit:5,3\": sync indices must be strictly increasing, got 3 after 5"
    );
    let err = parse_err(r#"{"trigerr": "const:100"}"#);
    assert!(
        err.starts_with("unknown config key \"trigerr\"; valid keys: "),
        "{err}"
    );
    assert!(err.contains("trigger"), "{err}");

    // Cross-field errors surface from resolve() with the same shape.
    let resolve_err = |cfg: &ExperimentConfig| cfg.resolve().expect_err("must reject").to_string();
    let cfg = ExperimentConfig {
        nodes: 4,
        link: "straggler:4:0.5".into(),
        ..Default::default()
    };
    assert_eq!(
        resolve_err(&cfg),
        "invalid link \"straggler:4:0.5\": straggler node 4 out of range for 4 nodes"
    );
    let cfg = ExperimentConfig {
        nodes: 16,
        topology: "torus".into(),
        topology_schedule: "switch:ring,torus:100".into(),
        ..Default::default()
    };
    assert_eq!(
        resolve_err(&cfg),
        "config sets both topology and topology_schedule: the schedule \
         \"switch:ring,torus:100\" names its own graphs, so the topology \"torus\" \
         would be ignored (try: remove one of the two; the schedule wins)"
    );
    let cfg = ExperimentConfig {
        compressor: "topk:100".into(),
        problem: "quadratic:64".into(),
        ..Default::default()
    };
    assert_eq!(
        resolve_err(&cfg),
        "invalid compressor \"topk:100\": k = 100 exceeds the problem dimension d = 64 \
         (try: k <= 64, or a percentage form like \"topk:10%\")"
    );
}

#[test]
fn structured_object_configs_hash_like_their_string_forms() {
    // The structured-JSON form is an input convenience only: it
    // canonicalizes to the same strings, so the hash (and resume id)
    // is identical to the legacy spelling.
    let string_form = Json::parse(
        r#"{"compressor": "sign_topk:10%", "trigger": "const:5000",
            "problem": "logreg:784:10:5", "nodes": 60, "h": 5}"#,
    )
    .unwrap();
    let object_form = Json::parse(
        r#"{"compressor": {"kind": "sign_topk", "k": "10%"},
            "trigger": {"kind": "const", "c0": 5000},
            "problem": {"kind": "logreg", "din": 784, "classes": 10, "batch": 5},
            "nodes": 60, "h": {"kind": "every", "h": 5}}"#,
    )
    .unwrap();
    let a = ExperimentConfig::from_json(&string_form).unwrap();
    let b = ExperimentConfig::from_json(&object_form).unwrap();
    assert_eq!(a, b);
    assert_eq!(config_hash(&a), config_hash(&b));
    assert_eq!(a.to_json().to_string(), b.to_json().to_string());
}
