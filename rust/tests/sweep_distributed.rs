//! Distributed sweep execution — the ISSUE-4 acceptance tests, run
//! against *real child processes* of the built `sparq` binary
//! (`CARGO_BIN_EXE_sparq`) sharing one output directory:
//!
//! * two concurrent `sparq sweep --distributed` processes split an
//!   8-run grid with **zero double-executed run ids** (claim files and
//!   `results.jsonl` agree) and merged series **bit-identical**
//!   (`f64::to_bits`) to a serial single-process sweep;
//! * a `--fault-abort-at`-killed process leaves its claims and mid-run
//!   checkpoints behind; after the lease expires a second process takes
//!   the claims over and *resumes* the half-finished runs from their
//!   checkpoints onto the uninterrupted trajectory;
//! * in-process: `run_distributed` with an early-stop target produces
//!   exactly the serial early-stopped result — same stop round, same
//!   bit-exact truncated prefix.

use std::path::{Path, PathBuf};
use std::process::{Command, Output, Stdio};

use sparq::config::ExperimentConfig;
use sparq::metrics::Series;
use sparq::sweep::{
    config_hash, run_configs, run_distributed, run_spec, ArtifactCache, DistributedOptions,
    SweepOptions, SweepSpec,
};

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("sparq-dist-{tag}-{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn assert_series_bits_eq(a: &Series, b: &Series, what: &str) {
    assert_eq!(a.records.len(), b.records.len(), "{what}: record counts");
    for (ra, rb) in a.records.iter().zip(b.records.iter()) {
        assert_eq!(ra.t, rb.t, "{what}: t");
        assert_eq!(ra.loss.to_bits(), rb.loss.to_bits(), "{what}: loss at t={}", ra.t);
        assert_eq!(
            ra.test_error.to_bits(),
            rb.test_error.to_bits(),
            "{what}: test_error at t={}",
            ra.t
        );
        assert_eq!(ra.opt_gap.to_bits(), rb.opt_gap.to_bits(), "{what}: opt_gap at t={}", ra.t);
        assert_eq!(ra.bits, rb.bits, "{what}: bits at t={}", ra.t);
        assert_eq!(ra.comm_rounds, rb.comm_rounds, "{what}: rounds at t={}", ra.t);
        assert_eq!(
            ra.consensus.to_bits(),
            rb.consensus.to_bits(),
            "{what}: consensus at t={}",
            ra.t
        );
        assert_eq!(ra.fired, rb.fired, "{what}: fired at t={}", ra.t);
    }
}

/// The shared 8-run grid: one base config × a seed axis.
fn grid_spec() -> SweepSpec {
    let base = ExperimentConfig {
        name: "dist-grid".into(),
        nodes: 5,
        steps: 160,
        eval_every: 40,
        problem: "quadratic:24".into(),
        compressor: "sign_topk:25%".into(),
        trigger: "const:20".into(),
        h: sparq::config::SyncSpec::every(2),
        ..Default::default()
    };
    SweepSpec::new("dist-grid")
        .base(&base)
        .axis_u64("seed", &[1, 2, 3, 4, 5, 6, 7, 8])
}

/// Serial single-process reference: id → series.
fn serial_reference(spec: &SweepSpec) -> Vec<(String, Series)> {
    let report = run_spec(
        spec,
        &SweepOptions {
            workers: 2,
            ..Default::default()
        },
    )
    .expect("serial sweep");
    report
        .outcomes
        .into_iter()
        .map(|o| (o.id, o.series))
        .collect()
}

fn write_spec(spec: &SweepSpec, dir: &Path) -> PathBuf {
    let path = dir.join("spec.json");
    std::fs::write(&path, spec.to_json().to_string_pretty()).unwrap();
    path
}

fn sparq_sweep(spec_path: &Path, out: &Path, extra: &[&str]) -> Command {
    let mut cmd = Command::new(env!("CARGO_BIN_EXE_sparq"));
    cmd.arg("sweep")
        .arg("--spec")
        .arg(spec_path)
        .arg("--out")
        .arg(out)
        .args(["--distributed=true", "--poll-ms", "50"])
        .args(extra)
        .stdout(Stdio::piped())
        .stderr(Stdio::piped());
    cmd
}

fn stdout_of(out: &Output) -> String {
    String::from_utf8_lossy(&out.stdout).to_string()
}

/// "N executed" from the child's summary line.
fn executed_count(stdout: &str) -> usize {
    let line = stdout
        .lines()
        .find(|l| l.contains("sweep complete:"))
        .unwrap_or_else(|| panic!("no summary line in:\n{stdout}"));
    let tail = line.split("sweep complete:").nth(1).unwrap();
    tail.trim()
        .split(' ')
        .next()
        .unwrap()
        .parse()
        .unwrap_or_else(|_| panic!("unparsable summary: {line}"))
}

fn claim_files(out: &Path) -> Vec<String> {
    let mut v = Vec::new();
    if let Ok(entries) = std::fs::read_dir(out.join("claims")) {
        for e in entries.flatten() {
            let name = e.file_name().to_string_lossy().to_string();
            if name.ends_with(".claim") {
                v.push(name.trim_end_matches(".claim").to_string());
            }
        }
    }
    v.sort();
    v
}

fn result_ids(out: &Path) -> Vec<String> {
    let text = std::fs::read_to_string(out.join("results.jsonl")).expect("results.jsonl");
    text.lines()
        .filter(|l| !l.trim().is_empty())
        .map(|l| {
            let j = sparq::util::json::Json::parse(l).expect("valid record line");
            j.get("id").and_then(|v| v.as_str().map(str::to_string)).expect("record id")
        })
        .collect()
}

#[test]
fn two_processes_split_the_grid_exactly_once_and_match_serial_bit_for_bit() {
    let spec = grid_spec();
    let reference = serial_reference(&spec);
    assert_eq!(reference.len(), 8);

    let dir = tmp_dir("two-procs");
    let out = dir.join("shared");
    let spec_path = write_spec(&spec, &dir);

    // Two live processes race the same grid; fresh claims keep each run
    // exclusive, so every id executes exactly once across the pair.
    let c1 = sparq_sweep(&spec_path, &out, &["--workers", "2", "--lease-secs", "30"])
        .spawn()
        .expect("spawn child 1");
    let c2 = sparq_sweep(&spec_path, &out, &["--workers", "2", "--lease-secs", "30"])
        .spawn()
        .expect("spawn child 2");
    let o1 = c1.wait_with_output().unwrap();
    let o2 = c2.wait_with_output().unwrap();
    assert!(
        o1.status.success(),
        "child 1 failed:\n{}\n{}",
        stdout_of(&o1),
        String::from_utf8_lossy(&o1.stderr)
    );
    assert!(
        o2.status.success(),
        "child 2 failed:\n{}\n{}",
        stdout_of(&o2),
        String::from_utf8_lossy(&o2.stderr)
    );

    // Exactly-once: 8 unique result ids matching the grid, no claims
    // left behind, and the two executed counts partition the grid.
    let mut ids = result_ids(&out);
    ids.sort();
    let mut expected: Vec<String> = reference.iter().map(|(id, _)| id.clone()).collect();
    expected.sort();
    assert_eq!(ids, expected, "every run id recorded exactly once");
    assert!(claim_files(&out).is_empty(), "all claims released");
    let (e1, e2) = (executed_count(&stdout_of(&o1)), executed_count(&stdout_of(&o2)));
    assert_eq!(e1 + e2, 8, "grid partitioned between the two processes ({e1} + {e2})");

    // Merged series bit-identical to the serial single-process sweep.
    for (id, serial) in &reference {
        let path = out.join("series").join(format!("{id}.jsonl"));
        let stored = Series::read_jsonl(&path, "stored").expect("stored series");
        assert_series_bits_eq(serial, &stored, &format!("run {id} (2-proc vs serial)"));
    }

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn killed_process_claims_are_taken_over_and_runs_resume_from_checkpoint() {
    let spec = grid_spec();
    let reference = serial_reference(&spec);

    let dir = tmp_dir("takeover");
    let out = dir.join("shared");
    let spec_path = write_spec(&spec, &dir);

    // Process 1 "crashes": fault injection aborts its first claimed run
    // at t = 80 (after the t = 40 and t = 80 checkpoints), leaving the
    // claim file and checkpoints in place and exiting nonzero.
    let o1 = sparq_sweep(
        &spec_path,
        &out,
        &[
            "--workers",
            "1",
            "--lease-secs",
            "1",
            "--checkpoint-every",
            "40",
            "--fault-abort-at",
            "80",
        ],
    )
    .output()
    .expect("run child 1");
    assert!(!o1.status.success(), "fault-injected child must exit nonzero");
    assert!(
        String::from_utf8_lossy(&o1.stderr).contains("fault injection"),
        "stderr: {}",
        String::from_utf8_lossy(&o1.stderr)
    );
    let abandoned = claim_files(&out);
    assert_eq!(abandoned.len(), 1, "exactly one abandoned claim: {abandoned:?}");
    let victim = &abandoned[0];
    // `sparq sweep status` lists the abandoned claim with its owner.
    let status = Command::new(env!("CARGO_BIN_EXE_sparq"))
        .args(["sweep", "status", "--out"])
        .arg(&out)
        .args(["--lease-secs", "1"])
        .stdout(Stdio::piped())
        .stderr(Stdio::piped())
        .output()
        .expect("sweep status");
    assert!(status.status.success());
    let status_out = stdout_of(&status);
    assert!(
        status_out.contains(victim.as_str()) && status_out.contains("1 claim(s) held"),
        "status must list the abandoned claim:\n{status_out}"
    );
    assert!(
        out.join("ckpt").join(format!("{victim}.ckpt")).exists(),
        "mid-run checkpoint left behind for takeover"
    );
    assert!(result_ids(&out).is_empty(), "no result recorded for the aborted run");

    // Let the lease expire, then a second process sweeps the grid: it
    // must take the stale claim over and resume the half-finished run
    // from its checkpoint (the verbose resume line proves it did not
    // restart from scratch — restarting would also be bit-identical).
    // Zero skew margin: one machine = one clock, and the test sleeps
    // only just past the 1s lease (the margin itself is unit-tested).
    std::thread::sleep(std::time::Duration::from_millis(1200));
    let o2 = sparq_sweep(
        &spec_path,
        &out,
        &[
            "--workers",
            "2",
            "--lease-secs",
            "1",
            "--lease-margin-secs",
            "0",
            "--checkpoint-every",
            "40",
        ],
    )
    .output()
    .expect("run child 2");
    assert!(
        o2.status.success(),
        "takeover child failed:\n{}\n{}",
        stdout_of(&o2),
        String::from_utf8_lossy(&o2.stderr)
    );
    let stdout = stdout_of(&o2);
    assert!(
        stdout.contains("resume") && stdout.contains("from t="),
        "takeover must resume from the checkpoint, not restart:\n{stdout}"
    );
    assert_eq!(executed_count(&stdout), 8, "second process finishes the whole grid");

    let mut ids = result_ids(&out);
    ids.sort();
    let mut expected: Vec<String> = reference.iter().map(|(id, _)| id.clone()).collect();
    expected.sort();
    assert_eq!(ids, expected, "all runs recorded exactly once after takeover");
    assert!(claim_files(&out).is_empty(), "takeover claims released");
    assert!(
        !out.join("ckpt").join(format!("{victim}.ckpt")).exists(),
        "completed run clears the inherited checkpoint"
    );

    // The resumed trajectory is the uninterrupted one, bit for bit.
    for (id, serial) in &reference {
        let path = out.join("series").join(format!("{id}.jsonl"));
        let stored = Series::read_jsonl(&path, "stored").expect("stored series");
        assert_series_bits_eq(serial, &stored, &format!("run {id} (takeover vs serial)"));
    }

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn distributed_early_stop_equals_serial_early_stop_bit_for_bit() {
    let cfg = ExperimentConfig {
        name: "dist-early".into(),
        nodes: 5,
        steps: 400,
        eval_every: 40,
        problem: "quadratic:24".into(),
        compressor: "sign_topk:25%".into(),
        trigger: "const:20".into(),
        h: sparq::config::SyncSpec::every(2),
        seed: 77,
        ..Default::default()
    };

    // Untruncated reference fixes a mid-run loss as the target.
    let full = run_configs(
        vec![("full".into(), cfg.clone())],
        &SweepOptions::default(),
        &ArtifactCache::new(),
    )
    .unwrap();
    let full = &full.outcomes[0].series;
    let target = full.records[5].loss;
    let stop_idx = full
        .records
        .iter()
        .position(|r| r.loss <= target)
        .expect("target reachable");

    let serial = run_configs(
        vec![("run".into(), cfg.clone())],
        &SweepOptions {
            target_loss: Some(target),
            ..Default::default()
        },
        &ArtifactCache::new(),
    )
    .unwrap();
    let serial = &serial.outcomes[0];

    let dir = tmp_dir("early-dist");
    let dist = run_distributed(
        vec![("run".into(), cfg.clone())],
        &SweepOptions {
            out: Some(dir.clone()),
            target_loss: Some(target),
            verbose: false,
            ..Default::default()
        },
        &DistributedOptions {
            lease_secs: 30.0,
            poll_ms: 20,
            ..Default::default()
        },
        &ArtifactCache::new(),
    )
    .unwrap();
    let dist = &dist.outcomes[0];

    assert_eq!(config_hash(&cfg), dist.id);
    assert!(serial.stopped.is_some() && dist.stopped.is_some());
    assert_eq!(serial.stopped, dist.stopped, "same stop round and reason");
    assert_eq!(serial.series.records.len(), stop_idx + 1);
    assert_series_bits_eq(&serial.series, &dist.series, "distributed vs serial early stop");

    // The truncated result is recorded (with its truncation) and a
    // second distributed pass loads it instead of re-running.
    let again = run_distributed(
        vec![("run".into(), cfg)],
        &SweepOptions {
            out: Some(dir.clone()),
            target_loss: Some(target),
            ..Default::default()
        },
        &DistributedOptions::default(),
        &ArtifactCache::new(),
    )
    .unwrap();
    assert_eq!(again.executed, 0);
    assert_eq!(again.skipped, 1);
    assert_eq!(again.outcomes[0].stopped, dist.stopped, "truncation survives the round-trip");
    assert_series_bits_eq(&again.outcomes[0].series, &dist.series, "stored truncated series");

    std::fs::remove_dir_all(&dir).ok();
}
