//! Sweep-engine system tests (ISSUE 3 acceptance) plus the
//! test-hardening satellites over the checkpoint and link layers:
//!
//! * the full Fig-1 grid (all five curves), expressed as a `SweepSpec`,
//!   runs concurrently and produces per-run series **bit-for-bit
//!   identical** to sweep-workers = 1, and resume skips completed runs;
//! * a fault-aborted run resumes from its mid-run checkpoint and lands
//!   on the uninterrupted trajectory bit for bit;
//! * `snapshot → save → load → restore` round-trips mid-run for SPARQ
//!   (with momentum), CHOCO, and vanilla — same final params and bus
//!   bits as never stopping;
//! * total delivered bits are monotonically non-increasing in the drop
//!   probability p on a fixed workload, and link-faulted runs are
//!   identical across worker counts.

use std::path::PathBuf;
use std::sync::{Arc, Mutex};

use sparq::comm::Bus;
use sparq::config::{Algo, ExperimentConfig};
use sparq::coordinator::checkpoint;
use sparq::experiments::{build_algo, build_problem, run_config};
use sparq::sweep::{
    run_configs, run_spec, ArtifactCache, EarlyStop, RunEvent, SweepOptions, SweepSpec,
};
use sparq::util::json::Json;
use sparq::util::Rng;

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("sparq-sweep-{tag}-{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    dir
}

/// Bit-for-bit series equality: every float compared by `to_bits` (the
/// CSV rendering rounds to ~6 significant figures, which is too coarse
/// for the acceptance criterion).
fn assert_series_bits_eq(a: &sparq::metrics::Series, b: &sparq::metrics::Series, what: &str) {
    assert_eq!(a.records.len(), b.records.len(), "{what}: record counts");
    for (ra, rb) in a.records.iter().zip(b.records.iter()) {
        assert_eq!(ra.t, rb.t, "{what}: t");
        assert_eq!(ra.loss.to_bits(), rb.loss.to_bits(), "{what}: loss at t={}", ra.t);
        assert_eq!(
            ra.test_error.to_bits(),
            rb.test_error.to_bits(),
            "{what}: test_error at t={}",
            ra.t
        );
        assert_eq!(
            ra.opt_gap.to_bits(),
            rb.opt_gap.to_bits(),
            "{what}: opt_gap at t={}",
            ra.t
        );
        assert_eq!(ra.bits, rb.bits, "{what}: bits at t={}", ra.t);
        assert_eq!(ra.comm_rounds, rb.comm_rounds, "{what}: rounds at t={}", ra.t);
        assert_eq!(
            ra.consensus.to_bits(),
            rb.consensus.to_bits(),
            "{what}: consensus at t={}",
            ra.t
        );
        assert_eq!(ra.fired, rb.fired, "{what}: fired at t={}", ra.t);
    }
}

/// The five fig1 convex curves as a sweep spec, scaled to test size
/// (same grid structure as `fig1::convex_spec`, smaller problem).
fn mini_fig1_spec(steps: u64, seed: u64) -> SweepSpec {
    let base = ExperimentConfig {
        name: "mini-fig1".into(),
        nodes: 8,
        steps,
        eval_every: 50,
        seed,
        problem: "logreg:24:4:6".into(),
        compressor: "sign_topk:25%".into(),
        trigger: "const:20".into(),
        lr: "invtime:100:1".into(),
        h: sparq::config::SyncSpec::every(5),
        ..Default::default()
    };
    SweepSpec::new("mini-fig1")
        .base(&base)
        .variant("SPARQ-SGD (SignTopK)", &[])
        .variant(
            "CHOCO-SGD (Sign)",
            &[("algo", Json::from("choco")), ("compressor", Json::from("sign"))],
        )
        .variant(
            "CHOCO-SGD (TopK)",
            &[("algo", Json::from("choco")), ("compressor", Json::from("topk:6"))],
        )
        .variant("CHOCO-SGD (SignTopK)", &[("algo", Json::from("choco"))])
        .variant(
            "Vanilla decentralized SGD",
            &[("algo", Json::from("vanilla")), ("compressor", Json::from("identity"))],
        )
}

#[test]
fn fig1_grid_sweep_is_bit_identical_across_budgets_and_resume_skips() {
    let spec = mini_fig1_spec(300, 11);
    assert_eq!(spec.len(), 5, "all five fig1 curves");

    let dir_serial = tmp_dir("serial");
    let dir_wide = tmp_dir("wide");
    let serial = run_spec(
        &spec,
        &SweepOptions {
            workers: 1,
            out: Some(dir_serial.clone()),
            ..Default::default()
        },
    )
    .expect("serial sweep");
    let wide = run_spec(
        &spec,
        &SweepOptions {
            workers: 8,
            out: Some(dir_wide.clone()),
            ..Default::default()
        },
    )
    .expect("concurrent sweep");
    assert_eq!(serial.executed, 5);
    assert_eq!(wide.executed, 5);

    // Per-run series bit-for-bit identical at workers = 1 vs 8.
    for a in &serial.outcomes {
        let b = wide.by_id(&a.id).expect("same run set");
        assert_series_bits_eq(&a.series, &b.series, &format!("{} (1 vs 8)", a.label));
        assert_eq!(a.fired, b.fired);
        assert_eq!(a.checks, b.checks);
    }

    // Resume on the serial dir: everything is already complete.
    let resumed = run_spec(
        &spec,
        &SweepOptions {
            workers: 8,
            out: Some(dir_serial.clone()),
            resume: true,
            ..Default::default()
        },
    )
    .expect("resumed sweep");
    assert_eq!(resumed.executed, 0, "resume must not re-run completed runs");
    assert_eq!(resumed.skipped, 5);
    for a in &serial.outcomes {
        let b = resumed.by_id(&a.id).expect("resumed run set");
        assert!(b.skipped);
        assert_series_bits_eq(&a.series, &b.series, &format!("{} (stored)", a.label));
        assert_eq!(a.fired, b.fired, "{}: fired stats not restored", a.label);
    }

    // A changed grid point is a different hash ⇒ re-runs; the rest skip.
    let mut spec2 = mini_fig1_spec(300, 11);
    spec2 = spec2.axis_u64("seed", &[12]);
    let moved = run_spec(
        &spec2,
        &SweepOptions {
            workers: 4,
            out: Some(dir_serial.clone()),
            resume: true,
            ..Default::default()
        },
    )
    .expect("shifted sweep");
    assert_eq!(moved.executed, 5, "new seeds are new runs");
    assert_eq!(moved.skipped, 0);

    std::fs::remove_dir_all(&dir_serial).ok();
    std::fs::remove_dir_all(&dir_wide).ok();
}

#[test]
fn sweep_mid_run_checkpoint_resume_is_bit_identical() {
    let cfg = ExperimentConfig {
        name: "ckpt-resume".into(),
        nodes: 6,
        steps: 200,
        eval_every: 50,
        problem: "quadratic:32".into(),
        compressor: "sign_topk:25%".into(),
        trigger: "const:20".into(),
        h: sparq::config::SyncSpec::every(2),
        momentum: 0.9,
        seed: 21,
        ..Default::default()
    };

    // Uninterrupted reference (no persistence).
    let cache = ArtifactCache::new();
    let reference = run_configs(
        vec![("ref".into(), cfg.clone())],
        &SweepOptions::default(),
        &cache,
    )
    .expect("reference run");
    let reference = &reference.outcomes[0];

    // Interrupted run: checkpoint every 60 iterations, die at t = 120.
    let dir = tmp_dir("ckpt");
    let interrupted = run_configs(
        vec![("run".into(), cfg.clone())],
        &SweepOptions {
            out: Some(dir.clone()),
            resume: true,
            checkpoint_every: 60,
            fault_abort_at: Some(120),
            ..Default::default()
        },
        &ArtifactCache::new(),
    )
    .expect("interrupted run");
    assert!(!interrupted.outcomes[0].completed);
    assert_eq!(interrupted.executed, 0, "aborted run is not 'executed'");
    let ckpt_file = dir.join("ckpt").join(format!("{}.ckpt", interrupted.outcomes[0].id));
    assert!(ckpt_file.exists(), "mid-run checkpoint written");
    let results = std::fs::read_to_string(dir.join("results.jsonl")).unwrap();
    assert!(results.trim().is_empty(), "no result recorded for an aborted run");

    // Resume: picks up at t = 120 from the snapshot, finishes the run.
    let resumed = run_configs(
        vec![("run".into(), cfg.clone())],
        &SweepOptions {
            out: Some(dir.clone()),
            resume: true,
            checkpoint_every: 60,
            ..Default::default()
        },
        &ArtifactCache::new(),
    )
    .expect("resumed run");
    let resumed = &resumed.outcomes[0];
    assert!(resumed.completed && !resumed.skipped);
    assert_series_bits_eq(
        &reference.series,
        &resumed.series,
        "resumed vs uninterrupted",
    );
    assert!(!ckpt_file.exists(), "completed run clears its snapshots");
    let results = std::fs::read_to_string(dir.join("results.jsonl")).unwrap();
    assert_eq!(results.lines().count(), 1, "exactly one result record");

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn checkpoint_roundtrip_bit_for_bit_for_all_three_algorithms() {
    // Satellite: snapshot → write → read → restore mid-run resumes to
    // exactly the same final params/bits as an uninterrupted run, for
    // SPARQ (with momentum), CHOCO, and vanilla.
    for (tag, algo, momentum) in [
        ("sparq", Algo::Sparq, 0.9),
        ("choco", Algo::Choco, 0.0),
        ("vanilla", Algo::Vanilla, 0.9),
    ] {
        let cfg = ExperimentConfig {
            name: format!("rt-{tag}"),
            algo,
            nodes: 6,
            steps: 240,
            problem: "quadratic:20".into(),
            compressor: "sign_topk:25%".into(),
            trigger: "const:10".into(),
            h: sparq::config::SyncSpec::every(2),
            momentum,
            seed: 31,
            ..Default::default()
        };
        let mut problem_a = build_problem(&cfg);
        let mut algo_a = build_algo(&cfg, problem_a.dim());
        let mut bus_a = Bus::new(cfg.nodes);
        let mut init_rng = Rng::new(cfg.seed ^ 0x1217);
        if let Some(x0) = problem_a.init_params(&mut init_rng) {
            algo_a.set_params(&x0);
        }
        for t in 0..120 {
            algo_a.step(t, problem_a.as_mut(), &mut bus_a);
        }

        // snapshot → write → read
        let ck = checkpoint::snapshot(algo_a.as_ref(), 120, &bus_a);
        let path = std::env::temp_dir()
            .join(format!("sparq-rt-{tag}-{}.ckpt", std::process::id()));
        ck.save(&path).expect("save");
        let loaded = sparq::coordinator::Checkpoint::load(&path).expect("load");
        assert_eq!(ck, loaded, "{tag}: checkpoint file round-trip");
        std::fs::remove_file(&path).ok();

        // restore into a FRESH algorithm + bus, continue both to t = 240
        let mut problem_b = build_problem(&cfg);
        let mut algo_b = build_algo(&cfg, problem_b.dim());
        let mut bus_b = Bus::new(cfg.nodes);
        checkpoint::restore(algo_b.as_mut(), &loaded).unwrap();
        checkpoint::restore_bus(&mut bus_b, &loaded);
        for t in 120..240 {
            algo_a.step(t, problem_a.as_mut(), &mut bus_a);
            algo_b.step(t, problem_b.as_mut(), &mut bus_b);
        }
        for i in 0..cfg.nodes {
            assert_eq!(
                algo_a.params(i),
                algo_b.params(i),
                "{tag}: node {i} params diverged after restore"
            );
            assert_eq!(
                algo_a.momentum(i),
                algo_b.momentum(i),
                "{tag}: node {i} momentum diverged"
            );
        }
        assert_eq!(bus_a.total_bits, bus_b.total_bits, "{tag}: bits diverged");
        assert_eq!(bus_a.node_bits, bus_b.node_bits, "{tag}: node bits diverged");
        assert_eq!(
            algo_a.fired_stats(),
            algo_b.fired_stats(),
            "{tag}: trigger stats diverged"
        );
    }
}

#[test]
fn delivered_bits_monotone_nonincreasing_in_drop_probability() {
    // Fixed workload (CHOCO + dense sign messages, so every broadcast
    // costs the same d+32 bits and every node transmits every round);
    // the link coins for a given (edge, t) are independent of p, so the
    // delivered set — and therefore the charged bits — can only shrink
    // as p grows.
    let bits_at = |p: f64| {
        let cfg = ExperimentConfig {
            name: format!("drop-{p}"),
            algo: Algo::Choco,
            nodes: 8,
            steps: 150,
            eval_every: 150,
            problem: "quadratic:24".into(),
            compressor: "sign".into(),
            link: (if p > 0.0 { format!("drop:{p}") } else { "none".to_string() }).into(),
            seed: 5,
            ..Default::default()
        };
        run_config(&cfg, false).records.last().unwrap().bits
    };
    let bits: Vec<u64> = [0.0, 0.2, 0.5, 0.8].iter().map(|&p| bits_at(p)).collect();
    for w in bits.windows(2) {
        assert!(
            w[0] >= w[1],
            "delivered bits increased with drop probability: {bits:?}"
        );
    }
    assert!(
        bits[3] < bits[0],
        "p=0.8 must drop something over 150 rounds: {bits:?}"
    );
}

#[test]
fn early_stop_is_deterministic_and_a_bit_exact_prefix_across_budgets() {
    // ISSUE-4 satellite: a run with a target stops at the same round
    // for workers 1 vs 8, and its truncated series is a bit-exact
    // prefix of the untruncated run's series.
    let cfg = ExperimentConfig {
        name: "early-loss".into(),
        nodes: 6,
        steps: 400,
        eval_every: 40,
        problem: "quadratic:32".into(),
        compressor: "sign_topk:25%".into(),
        trigger: "const:20".into(),
        h: sparq::config::SyncSpec::every(2),
        seed: 13,
        ..Default::default()
    };
    let full = run_configs(
        vec![("full".into(), cfg.clone())],
        &SweepOptions::default(),
        &ArtifactCache::new(),
    )
    .unwrap();
    let full = &full.outcomes[0].series;
    // A mid-run loss as the target: the first crossing defines the
    // expected stop record.
    let target = full.records[5].loss;
    let stop_idx = full
        .records
        .iter()
        .position(|r| r.loss <= target)
        .expect("target reachable");
    assert!(stop_idx + 1 < full.records.len(), "target must truncate the run");

    let mut per_budget = Vec::new();
    for workers in [1usize, 8] {
        let got = run_configs(
            vec![("run".into(), cfg.clone())],
            &SweepOptions {
                workers,
                target_loss: Some(target),
                ..Default::default()
            },
            &ArtifactCache::new(),
        )
        .unwrap();
        let got = got.outcomes.into_iter().next().unwrap();
        assert!(got.completed && !got.skipped);
        assert_eq!(
            got.stopped,
            Some(EarlyStop {
                t: full.records[stop_idx].t,
                reason: "target_loss".into(),
                target,
            }),
            "workers={workers}: stop record"
        );
        assert_eq!(got.series.records.len(), stop_idx + 1, "workers={workers}: prefix length");
        let mut prefix = sparq::metrics::Series::new("prefix");
        prefix.records = full.records[..=stop_idx].to_vec();
        assert_series_bits_eq(&prefix, &got.series, &format!("workers={workers} prefix"));
        per_budget.push(got);
    }
    assert_eq!(per_budget[0].fired, per_budget[1].fired, "trigger stats across budgets");
    assert_eq!(per_budget[0].checks, per_budget[1].checks);
}

#[test]
fn early_stop_target_error_truncates_and_roundtrips_through_resume() {
    // target_error variant (logreg has a real test set) + the recorded
    // truncation surviving a resume.
    let cfg = ExperimentConfig {
        name: "early-err".into(),
        nodes: 6,
        steps: 300,
        eval_every: 50,
        problem: "logreg:24:4:6".into(),
        compressor: "sign_topk:25%".into(),
        trigger: "const:20".into(),
        h: sparq::config::SyncSpec::every(2),
        seed: 19,
        ..Default::default()
    };
    let full = run_configs(
        vec![("full".into(), cfg.clone())],
        &SweepOptions::default(),
        &ArtifactCache::new(),
    )
    .unwrap();
    let full = &full.outcomes[0].series;
    // Target = a mid-run test error, so the stop lands mid-series.
    let target = full.records[full.records.len() / 2].test_error;
    let stop_idx = full
        .records
        .iter()
        .position(|r| r.test_error <= target)
        .expect("target reachable");

    let dir = tmp_dir("early-err");
    let opts = SweepOptions {
        out: Some(dir.clone()),
        resume: true,
        target_error: Some(target),
        ..Default::default()
    };
    let first = run_configs(
        vec![("run".into(), cfg.clone())],
        &opts,
        &ArtifactCache::new(),
    )
    .unwrap();
    let first = &first.outcomes[0];
    assert_eq!(
        first.stopped.as_ref().map(|s| (s.t, s.reason.clone())),
        Some((full.records[stop_idx].t, "target_error".to_string()))
    );
    assert_eq!(first.series.records.len(), stop_idx + 1);

    // Resume: the truncated run is complete — skipped, with the
    // truncation metadata and the exact stored prefix.
    let resumed = run_configs(
        vec![("run".into(), cfg.clone())],
        &opts,
        &ArtifactCache::new(),
    )
    .unwrap();
    let resumed = &resumed.outcomes[0];
    assert!(resumed.skipped);
    assert_eq!(resumed.stopped, first.stopped, "truncation recorded in results.jsonl");
    assert_series_bits_eq(&first.series, &resumed.series, "stored truncated series");

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn early_stop_frees_its_worker_for_a_pending_run() {
    // ISSUE-4 satellite: freed workers actually reassign. Three runs on
    // a 2-worker budget: A (quadratic — no test set, so a target_error
    // never stops it) runs long; B and C (logreg) early-stop at their
    // t = 0 evaluation because target_error = 1.0 is trivially met. The
    // worker that finishes B must pick up pending C while A is still
    // running — the event log pins the ordering.
    let quad = ExperimentConfig {
        name: "long-A".into(),
        nodes: 6,
        steps: 20000,
        eval_every: 5000,
        problem: "quadratic:64".into(),
        compressor: "sign_topk:25%".into(),
        trigger: "const:20".into(),
        h: sparq::config::SyncSpec::every(2),
        seed: 3,
        ..Default::default()
    };
    let logreg = |name: &str, seed: u64| ExperimentConfig {
        name: name.into(),
        problem: "logreg:16:3:4".into(),
        steps: 10000,
        eval_every: 1000,
        seed,
        ..quad.clone()
    };
    let events: Arc<Mutex<Vec<(String, String)>>> = Arc::new(Mutex::new(Vec::new()));
    let sink = Arc::clone(&events);
    let opts = SweepOptions {
        workers: 2,
        target_error: Some(1.0),
        on_event: Some(Arc::new(move |e: &RunEvent| {
            let mut v = sink.lock().unwrap();
            match e {
                RunEvent::Started { label, .. } => v.push(("start".into(), label.clone())),
                RunEvent::Finished { label, .. } => v.push(("finish".into(), label.clone())),
            }
        })),
        ..Default::default()
    };
    let report = run_configs(
        vec![
            ("A".into(), quad.clone()),
            ("B".into(), logreg("stop-B", 4)),
            ("C".into(), logreg("stop-C", 5)),
        ],
        &opts,
        &ArtifactCache::new(),
    )
    .unwrap();
    assert_eq!(report.executed, 3);
    assert!(report.outcomes[0].stopped.is_none(), "A runs to completion");
    for i in [1, 2] {
        let stop = report.outcomes[i].stopped.as_ref().expect("B/C early-stop");
        assert_eq!(stop.t, 0, "trivial target stops at the t=0 record");
        assert_eq!(stop.reason, "target_error");
        assert_eq!(report.outcomes[i].series.records.len(), 1);
    }
    let events = events.lock().unwrap();
    let pos = |kind: &str, label: &str| {
        events
            .iter()
            .position(|(k, l)| k == kind && l == label)
            .unwrap_or_else(|| panic!("missing event {kind}/{label}: {events:?}"))
    };
    assert!(
        pos("start", "C") < pos("finish", "A"),
        "pending run C must start before long run A finishes: {events:?}"
    );
}

#[test]
fn link_faulted_runs_are_identical_across_worker_counts() {
    let mk = |workers: usize| ExperimentConfig {
        name: "link-workers".into(),
        nodes: 8,
        steps: 200,
        eval_every: 100,
        problem: "quadratic:32".into(),
        compressor: "sign_topk:25%".into(),
        trigger: "const:10".into(),
        h: sparq::config::SyncSpec::every(2),
        link: "drop:0.3+straggler:2:0.5".into(),
        seed: 17,
        workers,
        ..Default::default()
    };
    let a = run_config(&mk(1), false);
    let b = run_config(&mk(8), false);
    assert_series_bits_eq(&a, &b, "faulted run across worker counts");
}
