//! Property-based tests over the system invariants, using the in-tree
//! harness (`util::prop`; proptest is unavailable offline — see DESIGN.md).
//!
//! Each property runs 48–64 randomized cases with seeded, replayable RNG
//! and scale-shrinking on failure.

use sparq::comm::wire;
use sparq::compress::{self, Compressor, QsgdOp, QsgdTopK, RandK, SignL1, SignTopK, SparseVec, TopK};
use sparq::graph::{metropolis_hastings, uniform_neighbor, SpectralInfo, Topology, TopologyKind};
use sparq::linalg::vecops::{dist2, norm2_sq};
use sparq::prop_assert;
use sparq::util::prop::{check, Config, G};
use sparq::util::Rng;

/// Every compressor kind the crate ships, at sparsity k, tagged (the
/// paper-accounting SignTopK variant reports the same `name()` as the
/// honest one, so tests must not distinguish kinds by name alone).
fn every_kind(k: usize) -> Vec<(&'static str, Box<dyn Compressor>)> {
    vec![
        ("identity", Box::new(compress::Identity)),
        ("sign", Box::new(SignL1)),
        ("topk", Box::new(TopK::new(k))),
        ("randk", Box::new(RandK::new(k))),
        ("qsgd", Box::new(QsgdOp::new(16))),
        ("sign_topk", Box::new(SignTopK::new(k))),
        ("sign_topk_paper", Box::new(SignTopK::paper_accounting(k))),
        ("qsgd_topk", Box::new(QsgdTopK::new(k, 8))),
    ]
}

fn any_topology(g: &mut G) -> Topology {
    let pick = g.usize_in(0, 5);
    match pick {
        0 => Topology::new(TopologyKind::Ring, g.usize_in(2, 40), 1),
        1 => Topology::new(TopologyKind::Complete, g.usize_in(2, 16), 1),
        2 => Topology::new(TopologyKind::Star, g.usize_in(2, 20), 1),
        3 => Topology::new(TopologyKind::Path, g.usize_in(2, 20), 1),
        4 => {
            let side = g.usize_in(2, 5);
            Topology::new(TopologyKind::Torus, side * side, 1)
        }
        _ => {
            let n = g.usize_in(6, 24);
            let d = g.usize_in(3, 5).min(n - 1);
            let d = if (n * d) % 2 == 1 { d - 1 } else { d }.max(2);
            Topology::new(TopologyKind::RandomRegular(d), n, g.usize_in(0, 1000) as u64)
        }
    }
}

#[test]
fn prop_mixing_matrices_always_valid() {
    check("mixing-valid", Config { cases: 64, seed: 0x11 }, |g| {
        let topo = any_topology(g);
        for mm in [uniform_neighbor(&topo), metropolis_hastings(&topo)] {
            if let Err(e) = mm.validate() {
                return Err(format!("{:?} n={}: {e}", topo.kind, topo.n));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_spectral_gap_in_unit_interval_for_connected_graphs() {
    check("spectral-gap", Config { cases: 48, seed: 0x22 }, |g| {
        let topo = any_topology(g);
        prop_assert!(topo.is_connected(), "{:?} disconnected", topo.kind);
        let s = SpectralInfo::compute(&uniform_neighbor(&topo));
        prop_assert!(
            s.delta > 0.0 && s.delta <= 1.0 + 1e-9,
            "{:?} n={} delta={}",
            topo.kind,
            topo.n,
            s.delta
        );
        prop_assert!((s.lambda1 - 1.0).abs() < 1e-8, "λ1 = {}", s.lambda1);
        prop_assert!(s.beta >= 0.0 && s.beta <= 2.0 + 1e-9, "β = {}", s.beta);
        // γ* well-formed for a sweep of ω
        for omega in [0.01, 0.25, 1.0] {
            let gamma = s.gamma_star(omega);
            prop_assert!(gamma > 0.0 && gamma <= 1.0, "γ*({omega}) = {gamma}");
        }
        Ok(())
    });
}

#[test]
fn prop_compression_contract_all_operators() {
    // Definition 1: E‖x − C(x)‖² ≤ (1 − ω)‖x‖². Deterministic operators
    // are checked on one draw, stochastic on an averaged estimate.
    check("compression-contract", Config { cases: 48, seed: 0x33 }, |g| {
        let d = g.dim(800).max(4);
        let x = g.vec_f32(d, 1.0);
        let k = g.usize_in(1, d);
        let ops: Vec<Box<dyn Compressor>> = vec![
            Box::new(TopK::new(k)),
            Box::new(SignTopK::new(k)),
            Box::new(SignL1),
            Box::new(RandK::new(k)),
            Box::new(QsgdOp::new(64)),
        ];
        for op in ops {
            let deterministic = matches!(op.name().as_str(), n if n.starts_with("topk") || n.starts_with("sign"));
            let reps = if deterministic { 1 } else { 120 };
            let mut rng = Rng::new(d as u64);
            let mut acc = 0.0;
            for _ in 0..reps {
                let q = op.compress_vec(&x, &mut rng);
                acc += dist2(&x, &q);
            }
            let err = acc / reps as f64;
            let bound = (1.0 - op.omega(d)) * norm2_sq(&x);
            prop_assert!(
                err <= bound * 1.10 + 1e-7,
                "{} d={d} k={k}: err {err} > bound {bound}",
                op.name()
            );
        }
        Ok(())
    });
}

#[test]
fn prop_compression_of_zero_is_zero() {
    check("c-of-zero", Config { cases: 16, seed: 0x44 }, |g| {
        let d = g.dim(500).max(2);
        let zero = vec![0.0f32; d];
        let ops: Vec<Box<dyn Compressor>> = vec![
            Box::new(TopK::new(1 + d / 7)),
            Box::new(SignTopK::new(1 + d / 7)),
            Box::new(RandK::new(1 + d / 7)),
            Box::new(QsgdOp::new(8)),
        ];
        for op in ops {
            let mut rng = Rng::new(1);
            let q = op.compress_vec(&zero, &mut rng);
            prop_assert!(
                q.iter().all(|v| *v == 0.0),
                "{}: C(0) != 0",
                op.name()
            );
        }
        Ok(())
    });
}

#[test]
fn prop_encoded_bits_never_exceed_uncompressed() {
    check("bits-bounded", Config { cases: 64, seed: 0x55 }, |g| {
        let d = g.dim(100_000).max(8);
        let k = g.usize_in(1, d / 2);
        let specs = [
            format!("topk:{k}"),
            format!("randk:{k}"),
            "sign".to_string(),
            format!("sign_topk:{k}"),
            "qsgd:16".to_string(),
        ];
        let full = 32 * d as u64;
        for spec in specs {
            let op = compress::parse(&spec, d).unwrap();
            let bits = op.encoded_bits(d);
            prop_assert!(
                bits <= full + 64,
                "{spec} d={d}: {bits} bits > uncompressed {full}"
            );
            prop_assert!(bits > 0, "{spec}: zero-cost message");
        }
        Ok(())
    });
}

#[test]
fn prop_consensus_preserves_average() {
    // One full SPARQ sync round never moves x̄ beyond the gradient step
    // (paper Eq. 20), for random graphs/compressors/triggers.
    use sparq::comm::Bus;
    use sparq::coordinator::{DecentralizedAlgo, SparqConfig, SparqSgd};
    use sparq::problems::{GradientSource, QuadraticProblem};
    use sparq::schedule::{LrSchedule, SyncSchedule};
    use sparq::trigger::{EventTrigger, ThresholdSchedule};

    check("avg-preserved", Config { cases: 24, seed: 0x66 }, |g| {
        let topo = any_topology(g);
        let n = topo.n;
        let d = g.usize_in(4, 40);
        let k = g.usize_in(1, d);
        let c0 = g.f64_in(0.0, 50.0);
        let cfg = SparqConfig {
            mixing: uniform_neighbor(&topo),
            compressor: Box::new(SignTopK::new(k)),
            trigger: EventTrigger::new(ThresholdSchedule::Constant(c0)),
            lr: LrSchedule::Constant(0.05),
            sync: SyncSchedule::EveryH(g.usize_in(1, 4) as u64),
            gamma: None,
            momentum: 0.0,
            seed: d as u64,
        };
        let mut algo = SparqSgd::new(cfg, d);
        let mut prob = QuadraticProblem::new(d, n, 0.5, 2.0, 0.0, 1.0, 77);
        let mut bus = Bus::new(n);

        for t in 0..12u64 {
            // Predict x̄^{t+1} = x̄^t − (η/n) Σ_i g_i(x_i) using noise-free
            // gradients evaluated at the *current* per-node params.
            let mut expected = algo.x_bar();
            let mut gsum = vec![0.0f32; d];
            let mut scratch = vec![0.0f32; d];
            let mut tmp_rng = Rng::new(0);
            for i in 0..n {
                prob.grad(i, algo.params(i), &mut tmp_rng, &mut scratch);
                for (a, b) in gsum.iter_mut().zip(scratch.iter()) {
                    *a += b;
                }
            }
            for (e, s) in expected.iter_mut().zip(gsum.iter()) {
                *e -= 0.05 * s / n as f32;
            }
            algo.step(t, &mut prob, &mut bus);
            let got = algo.x_bar();
            for (idx, (a, b)) in got.iter().zip(expected.iter()).enumerate() {
                prop_assert!(
                    (a - b).abs() < 1e-3 * (1.0 + b.abs()),
                    "t={t} coord {idx}: got {a}, expected {b} ({:?} n={n} d={d})",
                    topo.kind
                );
            }
        }
        Ok(())
    });
}

#[test]
fn prop_trigger_monotone_in_threshold() {
    // If a node fires at threshold c, it must also fire at any c' < c.
    use sparq::trigger::{EventTrigger, ThresholdSchedule};
    check("trigger-monotone", Config { cases: 64, seed: 0x77 }, |g| {
        let d = g.dim(300).max(2);
        let x = g.vec_f32(d, 1.0);
        let y = g.vec_f32(d, 1.0);
        let eta = g.f64_in(1e-4, 0.5);
        let c_hi = g.f64_in(0.0, 1e6);
        let c_lo = c_hi * g.f64_in(0.0, 1.0);
        let hi = EventTrigger::new(ThresholdSchedule::Constant(c_hi));
        let lo = EventTrigger::new(ThresholdSchedule::Constant(c_lo));
        if hi.fires(&x, &y, 3, eta) {
            prop_assert!(
                lo.fires(&x, &y, 3, eta),
                "fired at c={c_hi} but not at smaller c={c_lo}"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_sync_schedule_gap_respects_h() {
    use sparq::schedule::SyncSchedule;
    check("sync-gap", Config { cases: 64, seed: 0x88 }, |g| {
        let h = g.usize_in(1, 20) as u64;
        let s = SyncSchedule::EveryH(h);
        prop_assert!(s.gap(1000) == h, "gap {} != H {h}", s.gap(1000));
        // membership periodicity
        let t = g.usize_in(0, 500) as u64;
        let within = (t..t + h).any(|u| s.is_sync(u));
        prop_assert!(within, "no sync index within H={h} of t={t}");
        Ok(())
    });
}

#[test]
fn prop_compress_sparse_densifies_to_compress_for_every_kind() {
    // The sparse fast path's core contract, for EVERY compressor kind:
    // `compress_sparse` run on the same RNG stream densifies to exactly
    // the dense `compress` output, advances the stream identically, and
    // emits the canonical sparse form.
    check(
        "sparse-equals-dense-all-kinds",
        Config { cases: 48, seed: 0xC4 },
        |g| {
            let d = g.dim(500).max(4);
            let k = g.usize_in(1, d);
            let x = g.vec_f32(d, 1.0);
            let seed = g.usize_in(0, 1 << 30) as u64;
            for (tag, op) in every_kind(k) {
                let mut rng_dense = Rng::new(seed);
                let mut rng_sparse = Rng::new(seed);
                let dense = op.compress_vec(&x, &mut rng_dense);
                let mut q = SparseVec::new();
                op.compress_sparse(&x, &mut rng_sparse, &mut q);
                prop_assert!(
                    q.to_dense(d) == dense,
                    "{tag} d={d} k={k}: sparse != dense"
                );
                prop_assert!(
                    rng_dense.next_u64() == rng_sparse.next_u64(),
                    "{tag} d={d} k={k}: RNG streams diverged"
                );
                prop_assert!(
                    q.idx.windows(2).all(|w| w[0] < w[1]) && q.val.iter().all(|v| *v != 0.0),
                    "{tag} d={d} k={k}: non-canonical sparse form"
                );
            }
            Ok(())
        },
    );
}

#[test]
fn prop_message_bits_match_wire_codecs_for_every_kind() {
    // `message_bits(d, nnz)` is what the bus charges per message. For
    // kinds with a `comm::wire` codec (TopK, SignTopK, Sign) it must
    // equal the codec's encoded byte length ×8 for that EXACT message (up
    // to the final byte's padding), and the codec must round-trip. Kinds
    // with fixed-slot wire formats (Identity, RandK, QSGD, QsgdTopK)
    // charge their nominal `encoded_bits` regardless of stored nonzeros.
    check(
        "message-bits-wire-exact",
        Config { cases: 48, seed: 0xC5 },
        |g| {
            let d = g.dim(2048).max(8);
            let k = g.usize_in(1, d / 2);
            let x = g.vec_f32(d, 1.0);
            let seed = g.usize_in(0, 1 << 30) as u64;
            let byte_exact = |bytes: usize, charged: u64| -> bool {
                let bits = bytes as u64 * 8;
                bits >= charged && bits < charged + 8
            };
            for (tag, op) in every_kind(k) {
                let mut q = SparseVec::new();
                op.compress_sparse(&x, &mut Rng::new(seed), &mut q);
                let charged = op.message_bits(d, q.nnz());
                match tag {
                    "topk" => {
                        let bytes = wire::encode_topk_sparse(&q, d);
                        prop_assert!(
                            byte_exact(bytes.len(), charged),
                            "{tag} d={d}: {} bytes vs {charged} charged bits",
                            bytes.len()
                        );
                        let back =
                            wire::decode_topk(&bytes, d, q.nnz()).map_err(|e| e.to_string())?;
                        prop_assert!(back == q.to_dense(d), "{tag}: decode mismatch");
                    }
                    "sign_topk" | "sign_topk_paper" => {
                        let bytes = wire::encode_sign_topk_sparse(&q, d);
                        // The paper-accounting variant deliberately
                        // charges fewer bits (signs + norm, no indices)
                        // than the honest-indices codec emits — its
                        // charge is exact for ITS convention instead.
                        if tag == "sign_topk" {
                            prop_assert!(
                                byte_exact(bytes.len(), charged),
                                "{tag} d={d}: {} bytes vs {charged} charged bits",
                                bytes.len()
                            );
                        } else {
                            prop_assert!(
                                charged == q.nnz() as u64 + 32,
                                "{tag} d={d}: charged {charged} != nnz+32"
                            );
                        }
                        let back = wire::decode_sign_topk(&bytes, d, q.nnz())
                            .map_err(|e| e.to_string())?;
                        prop_assert!(back == q.to_dense(d), "{tag}: decode mismatch");
                    }
                    "sign" => {
                        let dense = q.to_dense(d);
                        let bytes = wire::encode_sign(&dense);
                        prop_assert!(
                            byte_exact(bytes.len(), charged),
                            "{tag} d={d}: {} bytes vs {charged} charged bits",
                            bytes.len()
                        );
                        prop_assert!(
                            wire::decode_sign(&bytes, d).map_err(|e| e.to_string())? == dense,
                            "{tag}: decode mismatch"
                        );
                    }
                    _ => {
                        // fixed-slot formats: nnz-independent nominal charge
                        prop_assert!(
                            charged == op.encoded_bits(d),
                            "{tag} d={d}: message_bits {charged} != nominal {}",
                            op.encoded_bits(d)
                        );
                        prop_assert!(
                            op.message_bits(d, 0) == op.message_bits(d, q.nnz()),
                            "{tag}: charge depends on nnz"
                        );
                    }
                }
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------
// Corruption-safe wire transport (frame + fault plans, ISSUE 6)
// ---------------------------------------------------------------------

#[test]
fn prop_framed_wire_codec_roundtrips_for_every_kind() {
    // The transport-shaped path every compressor output can take:
    // compress_sparse → self-describing codec → CRC frame → unframe →
    // decode. Clean frames must decode to exactly the compressed message
    // for EVERY operator kind.
    check("wire-frame-roundtrip", Config { cases: 48, seed: 0xD0 }, |g| {
        let d = g.dim(600).max(4);
        let k = g.usize_in(1, d);
        let x = g.vec_f32(d, 1.0);
        let seed = g.usize_in(0, 1 << 30) as u64;
        for (tag, op) in every_kind(k) {
            let mut q = SparseVec::new();
            op.compress_sparse(&x, &mut Rng::new(seed), &mut q);
            let framed = wire::frame(&wire::encode_sparse(&q, d));
            prop_assert!(framed.len() >= wire::FRAME_OVERHEAD, "{tag}: impossible frame");
            let payload = wire::unframe(&framed)
                .map_err(|e| format!("{tag} d={d}: clean frame rejected: {e}"))?;
            let back = wire::decode_sparse(payload, d)
                .map_err(|e| format!("{tag} d={d}: clean payload rejected: {e}"))?;
            prop_assert!(
                back.to_dense(d) == q.to_dense(d),
                "{tag} d={d} k={k}: framed roundtrip changed the message"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_single_bit_flip_is_always_detected_never_a_panic() {
    // CRC32 detects every single-bit error, so ANY one-bit flip anywhere
    // in a framed message must surface as Err from `unframe` — never a
    // panic, never a silent wrong decode.
    check("wire-bit-flip", Config { cases: 64, seed: 0xD1 }, |g| {
        let d = g.dim(400).max(4);
        let k = g.usize_in(1, d);
        let x = g.vec_f32(d, 1.0);
        let mut q = SparseVec::new();
        SignTopK::new(k).compress_sparse(&x, &mut Rng::new(7), &mut q);
        let clean = wire::frame(&wire::encode_sparse(&q, d));
        let mut framed = clean.clone();
        let bit = g.usize_in(0, framed.len() * 8 - 1);
        framed[bit / 8] ^= 1 << (bit % 8);
        prop_assert!(
            wire::unframe(&framed).is_err(),
            "flipped bit {bit} of {} slipped through the frame",
            framed.len() * 8
        );
        // Decoding damaged bytes without the frame must stay panic-free
        // (Err or a structurally-valid wrong value are both possible
        // there — the frame is what rules the latter out).
        let _ = wire::decode_sparse(&framed[wire::FRAME_OVERHEAD..], d);
        // Truncation at any byte boundary is an error, not a panic.
        let cut = g.usize_in(0, clean.len() - 1);
        prop_assert!(
            wire::unframe(&clean[..cut]).is_err(),
            "truncated frame accepted at {cut} of {} bytes",
            clean.len()
        );
        Ok(())
    });
}

#[test]
fn prop_fault_plans_are_deterministic_schedules_with_exact_windows() {
    use sparq::comm::FaultPlan;
    check("fault-plan", Config { cases: 64, seed: 0xD2 }, |g| {
        let n = g.usize_in(4, 24);
        // One crash window, one partition, one corruption rate, assembled
        // as the spec grammar string.
        let node = g.usize_in(0, n - 1);
        let down = g.usize_in(0, 200) as u64;
        let up = down + 1 + g.usize_in(0, 150) as u64;
        let p0 = g.usize_in(100, 250) as u64;
        let p1 = p0 + 1 + g.usize_in(0, 100) as u64;
        let cut = g.usize_in(1, n - 1); // groups [0, cut) | [cut, n)
        let p = g.f64_in(0.0, 0.9);
        let spec = format!(
            "crash:{node}:{down}:{up}+partition:{p0}:{p1}:0-{}|{}-{}+corrupt:{p:.4}",
            cut - 1,
            cut,
            n - 1
        );
        let seed = g.usize_in(0, 1 << 20) as u64;
        let plan = FaultPlan::parse(&spec, seed).map_err(|e| format!("{spec}: {e}"))?;
        let again = FaultPlan::parse(&spec, seed).map_err(|e| e.to_string())?;
        prop_assert!(plan == again, "{spec}: parse is not deterministic");
        plan.check_nodes(n).map_err(|e| format!("{spec}: {e}"))?;
        let probes = [0, down, up - 1, up, p0, p1 - 1, p1, 500];
        for t in probes {
            // the crash window is exactly [down, up)
            prop_assert!(
                plan.is_down(node, t) == (t >= down && t < up),
                "{spec}: is_down({node}, {t}) wrong"
            );
            // the partition severs exactly cross-group pairs in [p0, p1)
            prop_assert!(
                plan.severed(0, n - 1, t) == (t >= p0 && t < p1),
                "{spec}: severed(0, {}, {t}) wrong",
                n - 1
            );
            prop_assert!(
                !plan.severed(0, cut - 1, t),
                "{spec}: same-group pair severed at t={t}"
            );
            // corruption coins are pure functions of (seed, edge, round)
            prop_assert!(
                plan.corrupts(0, n - 1, t) == again.corrupts(0, n - 1, t),
                "{spec}: corrupt coin not deterministic at t={t}"
            );
        }
        // the empirical corruption rate tracks p
        if p > 0.05 {
            let trials = 2000u64;
            let hits = (0..trials).filter(|&t| plan.corrupts(1, 2, t)).count();
            let rate = hits as f64 / trials as f64;
            let slack = 0.05 + 3.0 * (p * (1.0 - p) / trials as f64).sqrt();
            prop_assert!(
                (rate - p).abs() < slack,
                "{spec}: corrupt rate {rate} far from p={p}"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_rng_streams_do_not_collide() {
    check("rng-streams", Config { cases: 32, seed: 0x99 }, |g| {
        let seed = g.usize_in(0, 1_000_000) as u64;
        let mut root = Rng::new(seed);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let mut same = 0;
        for _ in 0..64 {
            if a.next_u64() == b.next_u64() {
                same += 1;
            }
        }
        prop_assert!(same == 0, "{same}/64 collisions between forks");
        Ok(())
    });
}

// ---------------------------------------------------------------------
// Claim-lease semantics (sweep::distributed, ISSUE 4)
// ---------------------------------------------------------------------

/// A fresh claims directory per property case.
fn claims_dir(g: &mut G, tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!(
        "sparq-prop-claims-{tag}-{}-{:016x}",
        std::process::id(),
        g.rng.next_u64()
    ))
}

#[test]
fn prop_takeover_never_fires_before_the_lease_under_any_heartbeat_interleaving() {
    use sparq::sweep::{Acquire, ClaimStore};
    check("claim-lease", Config { cases: 48, seed: 0x41 }, |g| {
        let dir = claims_dir(g, "lease");
        let lease = g.f64_in(0.5, 50.0);
        let store_a =
            ClaimStore::new(&dir, "owner-a", lease).map_err(|e| format!("store a: {e}"))?;
        let store_b =
            ClaimStore::new(&dir, "owner-b", lease).map_err(|e| format!("store b: {e}"))?;
        let mut t = g.f64_in(0.0, 1.0e6);
        let mut claim = match store_a.try_acquire_at("r", t).map_err(|e| e.to_string())? {
            Acquire::Acquired(c) => c,
            Acquire::Held => return Err("fresh directory refused the first claim".into()),
        };
        let mut last_beat = t;
        let steps = g.usize_in(1, 12);
        let mut outcome = Ok(());
        for _ in 0..steps {
            // Arbitrary interleaving: time advances by anything from a
            // fraction of the lease to well past it, and either the
            // owner heartbeats or a rival probes.
            t += g.f64_in(0.0, lease * 1.4);
            if g.usize_in(0, 1) == 0 {
                // Owner heartbeat. B has not acquired yet, so A must
                // still own the claim.
                let alive = claim.heartbeat_at(t).map_err(|e| e.to_string())?;
                if !alive {
                    outcome = Err(format!(
                        "owner lost an untaken claim (lease {lease}, dt {})",
                        t - last_beat
                    ));
                    break;
                }
                last_beat = t;
            } else {
                let age = t - last_beat;
                match store_b.try_acquire_at("r", t).map_err(|e| e.to_string())? {
                    Acquire::Acquired(_) => {
                        if age < lease {
                            outcome = Err(format!(
                                "takeover fired {age}s after the last heartbeat \
                                 with a {lease}s lease"
                            ));
                        } else if claim.heartbeat_at(t).map_err(|e| e.to_string())? {
                            outcome =
                                Err("old owner's heartbeat survived a takeover".to_string());
                        }
                        break;
                    }
                    Acquire::Held => {
                        // An uncontended rival MUST take a stale claim.
                        if age >= lease {
                            outcome = Err(format!(
                                "stale claim (age {age}, lease {lease}) was not taken over"
                            ));
                            break;
                        }
                    }
                }
            }
        }
        std::fs::remove_dir_all(&dir).ok();
        outcome
    });
}

#[test]
fn prop_racing_claimants_yield_exactly_one_winner() {
    use sparq::sweep::{Acquire, ClaimStore};
    use std::sync::{Barrier, Mutex};
    check("claim-race", Config { cases: 12, seed: 0x42 }, |g| {
        let dir = claims_dir(g, "race");
        let n = g.usize_in(2, 8);
        // Phase 1: n claimants race create-exclusive on a fresh id.
        let wins = Mutex::new(0usize);
        let barrier = Barrier::new(n);
        std::thread::scope(|scope| {
            for i in 0..n {
                let dir = dir.clone();
                let wins = &wins;
                let barrier = &barrier;
                scope.spawn(move || {
                    let store = ClaimStore::new(&dir, format!("racer-{i}"), 3600.0)
                        .expect("claim store");
                    barrier.wait();
                    if let Ok(Acquire::Acquired(_)) = store.try_acquire("r") {
                        *wins.lock().unwrap() += 1;
                    }
                });
            }
        });
        let fresh_wins = *wins.lock().unwrap();
        prop_assert!(
            fresh_wins == 1,
            "{fresh_wins} of {n} racers acquired a fresh claim"
        );

        // Phase 2: the winner's claim is made stale (its stamp predates
        // the lease); n claimants race the takeover path. Exactly one
        // may win — the takeover only removes the stale file, while
        // acquisition still goes through create-exclusive.
        let store = ClaimStore::new(&dir, "restamper", 3600.0).expect("claim store");
        let stale_at = sparq::sweep::distributed::now_secs() - 2.0 * 3600.0;
        store
            .cleanup_stale_at("r", f64::INFINITY)
            .expect("clear phase-1 claim");
        match store.try_acquire_at("r", stale_at).expect("restamp") {
            Acquire::Acquired(_) => {}
            Acquire::Held => return Err("could not restamp the claim".into()),
        }
        let wins = Mutex::new(0usize);
        let barrier = Barrier::new(n);
        std::thread::scope(|scope| {
            for i in 0..n {
                let dir = dir.clone();
                let wins = &wins;
                let barrier = &barrier;
                scope.spawn(move || {
                    let store = ClaimStore::new(&dir, format!("taker-{i}"), 3600.0)
                        .expect("claim store");
                    barrier.wait();
                    if let Ok(Acquire::Acquired(_)) = store.try_acquire("r") {
                        *wins.lock().unwrap() += 1;
                    }
                });
            }
        });
        let takeover_wins = *wins.lock().unwrap();
        std::fs::remove_dir_all(&dir).ok();
        prop_assert!(
            takeover_wins == 1,
            "{takeover_wins} of {n} racers took over one stale claim"
        );
        Ok(())
    });
}

#[test]
fn prop_stale_claim_cleanup_is_idempotent() {
    use sparq::sweep::{Acquire, ClaimStore};
    check("claim-cleanup", Config { cases: 32, seed: 0x43 }, |g| {
        let dir = claims_dir(g, "cleanup");
        let lease = g.f64_in(0.1, 100.0);
        let t0 = g.f64_in(0.0, 1.0e6);
        let store = ClaimStore::new(&dir, "a", lease).map_err(|e| e.to_string())?;
        match store.try_acquire_at("r", t0).map_err(|e| e.to_string())? {
            Acquire::Acquired(_) => {}
            Acquire::Held => return Err("fresh claim refused".into()),
        }
        let other = ClaimStore::new(&dir, "b", lease).map_err(|e| e.to_string())?;
        // Before the lease: cleanup must refuse, repeatedly.
        let fresh = t0 + g.f64_in(0.0, lease * 0.99);
        prop_assert!(
            !other.cleanup_stale_at("r", fresh).map_err(|e| e.to_string())?,
            "cleanup removed a live claim (lease {lease})"
        );
        // After the lease: exactly the first cleanup removes it; every
        // repeat is a no-op returning false, and the id is acquirable
        // exactly once afterwards.
        let stale = t0 + lease + g.f64_in(0.0, lease);
        prop_assert!(
            other.cleanup_stale_at("r", stale).map_err(|e| e.to_string())?,
            "stale claim not cleaned up"
        );
        for _ in 0..g.usize_in(2, 5) {
            prop_assert!(
                !other.cleanup_stale_at("r", stale).map_err(|e| e.to_string())?,
                "cleanup of a removed claim must be a no-op"
            );
        }
        match other.try_acquire_at("r", stale).map_err(|e| e.to_string())? {
            Acquire::Acquired(_) => {}
            Acquire::Held => return Err("cleaned-up claim not acquirable".into()),
        }
        std::fs::remove_dir_all(&dir).ok();
        Ok(())
    });
}

// ---------------------------------------------------------------------
// Typed-config surface (the parse-don't-validate redesign)
// ---------------------------------------------------------------------

#[test]
fn prop_legacy_spec_strings_roundtrip_parse_display_parse() {
    use sparq::config::{
        CompressorSpec, LinkSpec, LrSpec, ProblemSpec, ScheduleSpec, SyncSpec, TopologySpec,
        TriggerSpec,
    };

    // Every legacy string form, with randomized parameters: parsing and
    // re-displaying is the identity on bytes (the typed specs preserve
    // the raw string — the property behind config_hash bit-compat), and
    // re-parsing the display yields an equal value.
    check("spec-roundtrip", Config { cases: 64, seed: 0xC0 }, |g| {
        let k = g.usize_in(1, 512);
        let pct = g.usize_in(1, 100);
        let s_level = g.usize_in(1, 32);
        let c0 = g.f64_in(0.0, 5000.0);
        let eps = g.f64_in(0.01, 0.99);
        let every = g.usize_in(1, 20);
        let until = g.usize_in(1, 100);
        let spe = g.usize_in(1, 500);
        let a = g.f64_in(0.1, 500.0);
        let b = g.f64_in(0.001, 10.0);
        let factor = g.f64_in(0.5, 10.0);
        let p = g.f64_in(0.0, 0.99);
        let node = g.usize_in(0, 63);
        let h = g.usize_in(1, 50) as u64;
        let (i1, gap) = (g.usize_in(1, 40) as u64, g.usize_in(1, 40) as u64);
        let period = g.usize_in(1, 2000);
        let d = g.usize_in(1, 4096);
        let noise = g.f64_in(0.0, 1.0);
        let classes = g.usize_in(2, 16);
        let batch = g.usize_in(1, 64);

        let specs: Vec<(&str, String)> = vec![
            ("compressor", "identity".into()),
            ("compressor", "sign".into()),
            ("compressor", format!("topk:{k}")),
            ("compressor", format!("randk:{k}")),
            ("compressor", format!("qsgd:{s_level}")),
            ("compressor", format!("sign_topk:{pct}%")),
            ("compressor", format!("sign_topk:{pct}%:paper")),
            ("compressor", format!("qsgd_topk:{k}:{s_level}")),
            ("trigger", "zero".into()),
            ("trigger", format!("const:{c0}")),
            ("trigger", format!("poly:{c0}:{eps}")),
            ("trigger", format!("piecewise:{c0}:{eps}:{every}:{until}:{spe}")),
            ("lr", format!("const:{b}")),
            ("lr", format!("invtime:{a}:{b}")),
            ("lr", format!("warmup:{b}:{every}:{factor}:{spe}:{until},{spe}")),
            ("link", "none".into()),
            ("link", format!("drop:{p}")),
            ("link", format!("drop:{p}+straggler:{node}:{p}")),
            ("h", format!("every:{h}")),
            ("h", format!("explicit:{i1},{}", i1 + gap)),
            ("topology", "ring".into()),
            ("topology", format!("regular{}", g.usize_in(1, 8))),
            ("topology_schedule", "static".into()),
            ("topology_schedule", format!("switch:ring,torus:{period}")),
            ("topology_schedule", format!("sample:complete:{}", g.usize_in(1, 6))),
            ("problem", format!("quadratic:{d}")),
            ("problem", format!("quadratic:{d}:{noise}:{noise}")),
            ("problem", format!("logreg:{d}:{classes}:{batch}")),
            ("problem", format!("mlp:{d}:{k}:{classes}:{batch}")),
        ];
        for (family, spec) in specs {
            // Macro-free dispatch: parse, display, re-parse, compare.
            macro_rules! roundtrip {
                ($ty:ty) => {{
                    let v: $ty = spec
                        .parse()
                        .map_err(|e| format!("{family} {spec:?} rejected: {e}"))?;
                    prop_assert!(
                        v.to_string() == spec,
                        "{family} {spec:?}: display changed to {:?}",
                        v.to_string()
                    );
                    let back: $ty = v
                        .to_string()
                        .parse()
                        .map_err(|e| format!("{family} re-parse failed: {e}"))?;
                    prop_assert!(back == v, "{family} {spec:?}: reparse differs");
                }};
            }
            match family {
                "compressor" => roundtrip!(CompressorSpec),
                "trigger" => roundtrip!(TriggerSpec),
                "lr" => roundtrip!(LrSpec),
                "link" => roundtrip!(LinkSpec),
                "h" => roundtrip!(SyncSpec),
                "topology" => roundtrip!(TopologySpec),
                "topology_schedule" => roundtrip!(ScheduleSpec),
                "problem" => roundtrip!(ProblemSpec),
                other => return Err(format!("unrouted family {other}")),
            }
        }
        Ok(())
    });
}

#[test]
fn prop_config_json_serialization_is_stable_under_roundtrip() {
    use sparq::config::ExperimentConfig;
    use sparq::sweep::config_hash;
    use sparq::util::json::Json;

    // from_json → to_json → from_json is the identity, and the
    // serialized bytes (what config_hash consumes) are stable.
    check("config-roundtrip", Config { cases: 48, seed: 0xC1 }, |g| {
        let compressors = ["sign", "topk:10%", "sign_topk:10", "qsgd:16", "identity"];
        let triggers = ["zero", "const:50", "poly:2:0.5", "piecewise:2.0:1.0:10:60:100"];
        let lrs = ["const:0.05", "invtime:100:1", "warmup:0.05:5:5:100:150,250"];
        let problems = ["quadratic:64", "quadratic:32:0.1:0.5", "logreg:24:4:8"];
        let links = ["none", "drop:0.1", "drop:0.2+straggler:0:0.5"];
        let j = Json::obj()
            .set("name", format!("prop-{}", g.usize_in(0, 999)))
            .set("nodes", g.usize_in(2, 32))
            .set("steps", g.usize_in(0, 5000))
            .set("eval_every", g.usize_in(1, 500))
            .set("seed", g.usize_in(0, 1 << 20))
            .set("h", g.usize_in(1, 20))
            .set("compressor", compressors[g.usize_in(0, compressors.len() - 1)])
            .set("trigger", triggers[g.usize_in(0, triggers.len() - 1)])
            .set("lr", lrs[g.usize_in(0, lrs.len() - 1)])
            .set("problem", problems[g.usize_in(0, problems.len() - 1)])
            .set("link", links[g.usize_in(0, links.len() - 1)]);
        let cfg = ExperimentConfig::from_json(&j).map_err(|e| e.to_string())?;
        let text = cfg.to_json().to_string();
        let back = ExperimentConfig::from_json(&Json::parse(&text).map_err(|e| e.to_string())?)
            .map_err(|e| e.to_string())?;
        prop_assert!(back == cfg, "config changed across JSON roundtrip");
        prop_assert!(
            back.to_json().to_string() == text,
            "serialization not byte-stable"
        );
        prop_assert!(
            config_hash(&back) == config_hash(&cfg),
            "config_hash not stable across roundtrip"
        );
        Ok(())
    });
}

#[test]
fn prop_lease_margin_widens_takeover_exactly() {
    use sparq::sweep::{Acquire, ClaimStore};

    // With a skew margin m, an uncontended stale claim is taken over at
    // stamp + lease + m and never before — the margin delays takeover by
    // exactly the allowance, under any (lease, margin) combination.
    check("claim-margin", Config { cases: 48, seed: 0x4D }, |g| {
        let dir = claims_dir(g, "margin");
        let lease = g.f64_in(1.0, 50.0);
        let margin = g.f64_in(0.0, 20.0);
        let t0 = g.f64_in(0.0, 1e6);
        let store_a = ClaimStore::new(&dir, "a", lease).map_err(|e| e.to_string())?;
        match store_a.try_acquire_at("r", t0).map_err(|e| e.to_string())? {
            Acquire::Acquired(_) => {}
            Acquire::Held => return Err("fresh directory refused the first claim".into()),
        }
        let store_b = ClaimStore::new(&dir, "b", lease)
            .map_err(|e| e.to_string())?
            .with_margin(margin)
            .map_err(|e| e.to_string())?;
        // Strictly inside lease + margin: must hold off.
        let early = t0 + (lease + margin) * g.f64_in(0.05, 0.99);
        prop_assert!(
            matches!(
                store_b.try_acquire_at("r", early).map_err(|e| e.to_string())?,
                Acquire::Held
            ),
            "takeover fired {:.3}s before lease {lease} + margin {margin}",
            t0 + lease + margin - early
        );
        // At/after lease + margin: must take over.
        let late = t0 + lease + margin + g.f64_in(0.001, 10.0);
        prop_assert!(
            matches!(
                store_b.try_acquire_at("r", late).map_err(|e| e.to_string())?,
                Acquire::Acquired(_)
            ),
            "stale claim (lease {lease}, margin {margin}) not taken over"
        );
        std::fs::remove_dir_all(&dir).ok();
        Ok(())
    });
}
