//! Quickstart: decentralized training with SPARQ-SGD through the typed
//! config + `Run` handle API, in ~40 lines.
//!
//! Eight nodes on a ring optimize a shared strongly-convex objective.
//! Each node takes H = 5 local SGD steps, then checks the event trigger;
//! only nodes whose parameters drifted enough broadcast a SignTopK-
//! compressed update before the gossip consensus step.
//!
//! Everything is a typed spec value — invalid compositions (a straggler
//! index past the node count, a torus on 7 nodes, k > d) fail at
//! `resolve()` with a structured error, before any training starts.
//!
//!     cargo run --release --example quickstart

use sparq::config::{CompressorSpec, ExperimentConfig, LrSpec, SyncSpec, TriggerSpec};
use sparq::run::Run;

fn main() {
    // 1. Algorithm 1's ingredients, as typed specs: compression operator
    //    C, trigger c_t, learning-rate schedule η_t, sync indices I_T.
    let cfg = ExperimentConfig {
        name: "quickstart".into(),
        nodes: 8,
        compressor: CompressorSpec::sign_top_k(64 / 4),
        trigger: TriggerSpec::poly(200.0, 0.5),
        lr: LrSpec::inv_time(60.0, 2.0),
        h: SyncSpec::every(5),
        steps: 4000,
        eval_every: 500,
        seed: 42,
        // Known optimum, σ = 0.1 gradient noise, 0.5 heterogeneity.
        problem: "quadratic:64:0.1:0.5".into(),
        ..Default::default()
    };

    // 2. Parse-don't-validate: one resolve() call proves the whole
    //    composition coherent; everything after this cannot fail on
    //    config grounds.
    let resolved = cfg.resolve().unwrap_or_else(|e| panic!("config error: {e}"));

    // 3. A Run handle owns the problem, the engine, and the bus.
    let mut run = Run::from_resolved(&resolved, None, 1);
    println!("{:>6} {:>12} {:>14} {:>12} {:>8}", "t", "opt gap", "consensus", "bits", "fired");
    while !run.done() {
        run.step();
        if run.t() % 500 == 0 {
            let rec = run.eval();
            println!(
                "{:>6} {:>12.6} {:>14.6} {:>12} {:>5}",
                rec.t, rec.opt_gap, rec.consensus, rec.bits, rec.fired
            );
        }
    }

    let (fired, checks) = run.fired_stats();
    let gap = run.series().records.last().unwrap().opt_gap;
    println!(
        "\ndone: suboptimality {:.2e}; {} bits total; trigger fired {}/{} checks ({:.0}% silent)",
        gap,
        run.bus().total_bits,
        fired,
        checks,
        100.0 * (1.0 - fired as f64 / checks.max(1) as f64)
    );
    assert!(gap < 0.1, "quickstart failed to converge (gap {gap})");
}
