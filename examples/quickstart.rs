//! Quickstart: decentralized training with SPARQ-SGD in ~40 lines.
//!
//! Eight nodes on a ring optimize a shared strongly-convex objective.
//! Each node takes H = 5 local SGD steps, then checks the event trigger;
//! only nodes whose parameters drifted enough broadcast a SignTopK-
//! compressed update before the gossip consensus step.
//!
//!     cargo run --release --example quickstart

use sparq::comm::Bus;
use sparq::compress::SignTopK;
use sparq::coordinator::{DecentralizedAlgo, SparqConfig, SparqSgd};
use sparq::graph::{uniform_neighbor, Topology, TopologyKind};
use sparq::problems::QuadraticProblem;
use sparq::schedule::{LrSchedule, SyncSchedule};
use sparq::trigger::{EventTrigger, ThresholdSchedule};

fn main() {
    let (n, d) = (8, 64);

    // 1. Communication graph + doubly-stochastic mixing weights.
    let topology = Topology::new(TopologyKind::Ring, n, 0);
    let mixing = uniform_neighbor(&topology);

    // 2. Algorithm 1's ingredients: compression operator C, trigger c_t,
    //    learning-rate schedule η_t, sync indices I_T (gap H).
    let cfg = SparqConfig {
        mixing,
        compressor: Box::new(SignTopK::new(d / 4)),
        trigger: EventTrigger::new(ThresholdSchedule::Poly { c0: 200.0, eps: 0.5 }),
        lr: LrSchedule::InverseTime { a: 60.0, b: 2.0 },
        sync: SyncSchedule::EveryH(5),
        gamma: None, // tuned γ from the spectral gap; Some(γ) to override
        momentum: 0.0,
        seed: 42,
    };
    let mut algo = SparqSgd::new(cfg, d);

    // 3. A problem with a known optimum so we can watch the true gap.
    let mut problem = QuadraticProblem::new(d, n, 0.5, 2.0, 0.1, 0.5, 7);
    let mut bus = Bus::new(n);

    println!("γ = {:.4}, δ = {:.4}", algo.gamma, algo.spectral().delta);
    println!("{:>6} {:>12} {:>14} {:>12} {:>8}", "t", "f(x̄)−f*", "consensus", "bits", "fired");
    for t in 0..4000u64 {
        algo.step(t, &mut problem, &mut bus);
        if (t + 1) % 500 == 0 {
            println!(
                "{:>6} {:>12.6} {:>14.6} {:>12} {:>5}/{}",
                t + 1,
                problem.suboptimality(&algo.x_bar()),
                algo.consensus_distance(),
                bus.total_bits,
                algo.total_fired,
                algo.total_checks,
            );
        }
    }
    let gap = problem.suboptimality(&algo.x_bar());
    println!(
        "\ndone: suboptimality {:.2e}; {} bits total; trigger fired {}/{} checks ({:.0}% silent)",
        gap,
        bus.total_bits,
        algo.total_fired,
        algo.total_checks,
        100.0 * (1.0 - algo.total_fired as f64 / algo.total_checks.max(1) as f64)
    );
    assert!(gap < 0.05, "quickstart failed to converge (gap {gap})");
}
