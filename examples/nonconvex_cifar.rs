//! Figure 1c/1d driver — non-convex objective (Section 5.2).
//!
//! Synthetic-CIFAR MLP on an n = 8 ring with momentum 0.9, H = 5 local
//! steps, SignTopK top-10% compression and the piecewise trigger schedule
//! (2.0, +1.0 every 10 epochs until 60). Baselines: SPARQ without the
//! trigger ("SPARQ (Sign-TopK)" in the paper's Fig 1c/1d), CHOCO-SGD
//! (Sign / TopK) and vanilla decentralized SGD.
//!
//! Default model is the scaled 512→64→10 MLP (DESIGN.md §Substitutions;
//! pass --problem mlp:3072:128:10:32 for the paper-sized stand-in if you
//! have minutes to spare).
//!
//!     cargo run --release --example nonconvex_cifar -- [--steps 3000]
//!         [--steps-per-epoch 100] [--target-err 0.2] [--out results/]

use sparq::experiments::{fig1, savings};
use sparq::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let steps = args.u64("steps", 3000);
    let spe = args.usize("steps-per-epoch", 100);
    let seed = args.u64("seed", 42);
    let target = args.f64("target-err", 0.2);
    let problem = args.get_or("problem", "mlp:512:64:10:16");

    println!("Figure 1c/1d: non-convex, n=8 ring, momentum 0.9, H=5");
    println!("model {problem}, steps {steps} ({} epochs)\n", steps as usize / spe);

    let suite = fig1::nonconvex_suite(steps, spe, seed, &problem);
    let series = fig1::run_suite(suite, true);

    println!("\n--- Fig 1c: training loss vs epoch ---");
    for s in &series {
        let pts: Vec<String> = s
            .records
            .iter()
            .step_by((s.records.len() / 8).max(1))
            .map(|r| format!("({:.1}, {:.3})", r.t as f64 / spe as f64, r.loss))
            .collect();
        println!("{:<42} {}", s.label, pts.join(" "));
    }

    println!("\n--- Fig 1d: top-1 accuracy vs total bits ---");
    for s in &series {
        let pts: Vec<String> = s
            .records
            .iter()
            .step_by((s.records.len() / 8).max(1))
            .map(|r| format!("({:.2e}, {:.3})", r.bits as f64, 1.0 - r.test_error))
            .collect();
        println!("{:<42} {}", s.label, pts.join(" "));
    }

    println!("\n--- bits to reach test error ≤ {target} (top-1 ≥ {:.0}%) ---", (1.0 - target) * 100.0);
    println!("{}", fig1::savings_table(&series, target));

    for (idx, label) in [
        (1, "SPARQ-no-trigger"),
        (2, "CHOCO-Sign"),
        (3, "CHOCO-TopK"),
        (4, "vanilla"),
    ] {
        if let Some(f) = savings::savings_factor(&series, 0, idx, target) {
            println!("SPARQ saves {f:.0}x bits vs {label}");
        }
    }

    if let Some(out) = args.get("out") {
        std::fs::create_dir_all(out).ok();
        for s in &series {
            let fname = s.label.replace([' ', '(', ')', '/', ','], "_") + ".csv";
            let p = std::path::Path::new(out).join(fname);
            s.write_csv(&p).expect("write");
            println!("wrote {}", p.display());
        }
    }
}
