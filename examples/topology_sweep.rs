//! Topology / spectral-gap sweep (paper footnote 5: expander graphs give
//! constant degree *and* large spectral gap — the design sweet spot).
//!
//! For each topology: δ, β, γ*, the tuned γ, then a fixed-budget SPARQ run
//! reporting final suboptimality and total bits. Shows the paper's
//! Remark 1(iv) trade-off measured: rings are cheap per round but mix
//! slowly; complete graphs mix in one hop but cost O(n) links; random
//! regular graphs get most of the mixing at constant degree.
//!
//!     cargo run --release --example topology_sweep -- [--nodes 16]
//!         [--steps 3000]

use sparq::experiments::rates;
use sparq::graph::{uniform_neighbor, SpectralInfo, Topology, TopologyKind};
use sparq::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let n = args.usize("nodes", 16);
    let steps = args.u64("steps", 3000);

    let topologies: Vec<(&str, TopologyKind)> = vec![
        ("ring", TopologyKind::Ring),
        ("path", TopologyKind::Path),
        ("torus", TopologyKind::Torus),
        ("regular4 (expander)", TopologyKind::RandomRegular(4)),
        ("hypercube", TopologyKind::Hypercube),
        ("star", TopologyKind::Star),
        ("complete", TopologyKind::Complete),
    ];

    println!(
        "{:<22} {:>4} {:>9} {:>8} {:>10} {:>12} {:>14} {:>12}",
        "topology", "deg", "δ", "β", "γ*(ω=.1)", "final gap", "bits", "edges"
    );
    for (name, kind) in topologies {
        // torus/hypercube need compatible n
        let n_eff = match kind {
            TopologyKind::Torus => {
                let side = (n as f64).sqrt().round() as usize;
                side * side
            }
            TopologyKind::Hypercube => n.next_power_of_two(),
            _ => n,
        };
        let topo = Topology::new(kind, n_eff, 3);
        let mm = uniform_neighbor(&topo);
        let s = SpectralInfo::compute(&mm);
        let point = rates::run_point(n_eff, 32, 5, 1.0, 0.25, kind, steps, 11);
        println!(
            "{:<22} {:>4} {:>9.5} {:>8.4} {:>10.6} {:>12.6} {:>14} {:>12}",
            name,
            topo.max_degree(),
            s.delta,
            s.beta,
            s.gamma_star(0.1),
            point.final_gap,
            point.total_bits,
            topo.edge_count(),
        );
    }
    println!(
        "\nreading: larger δ ⇒ faster consensus at equal T; the expander\n\
         matches hypercube-like gaps at constant degree — footnote 5's point."
    );
}
