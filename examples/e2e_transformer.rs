//! End-to-end driver: decentralized training of a byte-level transformer
//! LM through the **full three-layer stack**.
//!
//! * gradients come from the AOT `lm_grad` HLO artifact (L2 JAX fwd/bwd,
//!   lowered once by `python/compile/aot.py`) executed on the PJRT CPU
//!   client — Python is not running;
//! * the L3 coordinator runs Algorithm 1 verbatim: H local steps, event
//!   trigger, SignTopK compression, gossip consensus, exact bit
//!   accounting — over an n-node ring;
//! * each node holds an independent shard of a synthetic byte corpus.
//!
//! Run `make artifacts` first, then:
//!
//!     cargo run --release --example e2e_transformer -- [--steps 300]
//!         [--nodes 4] [--eval-every 20] [--out results/e2e.csv]
//!
//! The loss curve (from ~ln 256 ≈ 5.55 downward) is recorded in
//! EXPERIMENTS.md §E2E.

use sparq::coordinator::{DecentralizedAlgo, SparqConfig, SparqSgd};
use sparq::data::corpus::{generate_corpus, LmBatcher};
use sparq::metrics::RoundRecord;
use sparq::problems::GradientSource;
use sparq::run::{Run, RunObserver};
use sparq::graph::{uniform_neighbor, Topology, TopologyKind};
use sparq::runtime::{Manifest, Runtime};
use sparq::runtime::model::PjrtLm;
use sparq::schedule::{LrSchedule, SyncSchedule};
use sparq::trigger::{EventTrigger, ThresholdSchedule};
use sparq::util::cli::Args;
use sparq::util::Rng;

fn main() {
    let args = Args::from_env();
    let steps = args.u64("steps", 300);
    let n = args.usize("nodes", 4);
    let eval_every = args.u64("eval-every", 20);

    let Some(manifest) = Manifest::load_default() else {
        eprintln!("artifacts/manifest.json not found — run `make artifacts` first");
        std::process::exit(1);
    };
    let rt = Runtime::new(manifest).expect("PJRT CPU client");
    println!("PJRT platform: {}", rt.platform());

    // Per-node corpus shards (independent seeds ⇒ heterogeneous-ish data).
    let shards: Vec<LmBatcher> = (0..n)
        .map(|i| LmBatcher::new(generate_corpus(64 * 1024, 1000 + i as u64), 64))
        .collect();
    let mut model = PjrtLm::new(rt, shards, 0xE7A1).expect("lm artifacts");
    let d = model.dim;
    println!(
        "transformer: d = {d} parameters, batch {} x seq {}, {n}-node ring",
        model.batch, model.seq
    );

    // Shared Glorot-ish init (all nodes start identical, as in the paper).
    let mut init_rng = Rng::new(7);
    let mut x0 = vec![0.0f32; d];
    init_rng.fill_normal(&mut x0, 0.02);

    let topo = Topology::new(TopologyKind::Ring, n, 0);
    let cfg = SparqConfig {
        mixing: uniform_neighbor(&topo),
        compressor: sparq::compress::parse("sign_topk:10%", d).unwrap(),
        trigger: EventTrigger::new(ThresholdSchedule::Constant(50.0)),
        lr: LrSchedule::Constant(0.05),
        sync: SyncSchedule::EveryH(5),
        gamma: None,
        momentum: 0.9,
        seed: 42,
    };
    let mut algo = SparqSgd::new(cfg, d);
    algo.init_params(&x0);

    // Drive the borrowed algorithm/model pair through the Run handle —
    // the same loop the sweep engine uses, with a progress observer.
    struct Progress;
    impl RunObserver for Progress {
        fn evaluated(&mut self, r: &RoundRecord, _done: bool) -> bool {
            println!(
                "  t={:<7} loss={:.4} bits={} rounds={} consensus={:.3e}",
                r.t, r.loss, r.bits, r.comm_rounds, r.consensus
            );
            false
        }
    }
    algo.set_workers(args.usize("workers", 1));
    let t0 = std::time::Instant::now();
    let mut training = Run::new(
        &mut algo as &mut dyn DecentralizedAlgo,
        &mut model as &mut dyn GradientSource,
        steps,
        eval_every,
        "e2e-transformer".to_string(),
    );
    training.drive(&mut Progress).expect("observer cannot fail");
    let series = training.into_series();
    let wall = t0.elapsed().as_secs_f64();

    let first = &series.records[0];
    let last = series.records.last().unwrap();
    println!(
        "\nE2E summary: {} steps in {:.1}s ({:.1} ms/node-step incl. eval)",
        steps,
        wall,
        1000.0 * wall / (steps as f64 * n as f64)
    );
    println!(
        "loss {:.4} -> {:.4} (init ≈ ln 256 = 5.545); bits {}; comm rounds {}; fired {}/{}",
        first.loss, last.loss, last.bits, last.comm_rounds, algo.total_fired, algo.total_checks
    );
    assert!(last.loss < first.loss, "E2E training must reduce loss");

    if let Some(out) = args.get("out") {
        if let Some(dir) = std::path::Path::new(out).parent() {
            std::fs::create_dir_all(dir).ok();
        }
        series.write_csv(std::path::Path::new(out)).expect("write csv");
        println!("wrote {out}");
    }
}
