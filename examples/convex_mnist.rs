//! Figure 1a/1b driver — convex objective (Section 5.1).
//!
//! Synthetic-MNIST logistic regression (784→10, d = 7850) on an n = 60
//! ring with heterogeneous by-class shards; SPARQ-SGD (SignTopK k = 10,
//! trigger c₀ = 5000, H = 5, η_t = 1/(t+100)) against CHOCO-SGD (Sign /
//! TopK / SignTopK) and vanilla decentralized SGD.
//!
//! Prints the two panels as data series (test error vs comm rounds, test
//! error vs cumulative bits) plus the bits-to-target savings table the
//! paper quotes (250× vs CHOCO-Sign, ~1000× vs vanilla).
//!
//!     cargo run --release --example convex_mnist -- [--steps 4000]
//!         [--target-err 0.15] [--out results/convex]

use sparq::experiments::fig1;
use sparq::experiments::savings;
use sparq::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let steps = args.u64("steps", 4000);
    let seed = args.u64("seed", 42);
    let target = args.f64("target-err", 0.15);

    println!("Figure 1a/1b: convex, n=60 ring, d=7850, H=5, SignTopK(k=10)");
    println!("steps per curve: {steps}\n");

    let suite = fig1::convex_suite(steps, seed);
    let series = fig1::run_suite(suite, true);

    println!("\n--- Fig 1a: test error vs communication rounds ---");
    for s in &series {
        let pts: Vec<String> = s
            .records
            .iter()
            .step_by((s.records.len() / 8).max(1))
            .map(|r| format!("({}, {:.3})", r.comm_rounds, r.test_error))
            .collect();
        println!("{:<38} {}", s.label, pts.join(" "));
    }

    println!("\n--- Fig 1b: test error vs total bits ---");
    for s in &series {
        let pts: Vec<String> = s
            .records
            .iter()
            .step_by((s.records.len() / 8).max(1))
            .map(|r| format!("({:.2e}, {:.3})", r.bits as f64, r.test_error))
            .collect();
        println!("{:<38} {}", s.label, pts.join(" "));
    }

    println!("\n--- bits to reach test error ≤ {target} ---");
    println!("{}", fig1::savings_table(&series, target));

    // Headline factors (SPARQ is series[0]).
    for (idx, label) in [(1, "CHOCO-Sign"), (2, "CHOCO-TopK"), (4, "vanilla")] {
        if let Some(f) = savings::savings_factor(&series, 0, idx, target) {
            println!("SPARQ saves {f:.0}x bits vs {label}");
        }
    }

    if let Some(out) = args.get("out") {
        std::fs::create_dir_all(out).ok();
        for s in &series {
            let fname = s.label.replace([' ', '(', ')', '/'], "_") + ".csv";
            let p = std::path::Path::new(out).join(fname);
            s.write_csv(&p).expect("write");
            println!("wrote {}", p.display());
        }
    }
}
